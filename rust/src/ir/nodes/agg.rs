//! (Dis-)aggregation combinators: Concat, Bcast, Group, Ungroup, Flatmap
//! (§4 Fig. 3). These recover forms of batching inside the streaming
//! runtime — e.g. the GGSNN groups all edges of one type into a single
//! batched linear-layer message. All join buffers and backward records
//! live in the runtime stash, which also threads the version tags and
//! the train flag through every one of them (the "glue zoo" can no
//! longer drop the staleness wire protocol).

use anyhow::{anyhow, Result};

use crate::ir::graph::{Node, PortId};
use crate::ir::rt::NodeCtx;
use crate::ir::state::MsgState;
use crate::tensor::{ops, Tensor};

use super::single;

pub type KeyFn = Box<dyn Fn(&MsgState) -> crate::ir::state::StateKey + Send>;
pub type CountFn = Box<dyn Fn(&MsgState) -> usize + Send>;
pub type OrderFn = Box<dyn Fn(&MsgState) -> usize + Send>;
pub type MergeFn = Box<dyn Fn(&MsgState, usize) -> MsgState + Send>;
pub type StatesFn = Box<dyn Fn(&MsgState) -> Vec<MsgState> + Send>;

// ================================================================ Concat ====

/// Join buffer: one tensor per input port.
struct ConcatJoin(Vec<Option<Tensor>>);

/// Column widths recorded at the join for the backward split.
struct Widths(Vec<usize>);

/// Concat: join one message per input port (same state) into a single
/// message whose tensor is the column-concatenation. Backward splits the
/// cotangent by the recorded widths. Used for `[embedding, h]` in the RNN.
pub struct ConcatNode {
    label: String,
    n_in: usize,
}

impl ConcatNode {
    pub fn new(label: &str, n_in: usize) -> Self {
        assert!(n_in >= 2);
        ConcatNode { label: label.to_string(), n_in }
    }
}

impl Node for ConcatNode {
    fn forward(
        &mut self,
        port: PortId,
        state: MsgState,
        payload: Vec<Tensor>,
        ctx: &mut NodeCtx,
    ) -> Result<()> {
        anyhow::ensure!(port < self.n_in, "{}: bad port {port}", self.label);
        let t = single(&self.label, &payload)?.clone();
        let key = state.key();
        let mut join =
            ctx.take::<ConcatJoin>(key).unwrap_or_else(|| ConcatJoin(vec![None; self.n_in]));
        anyhow::ensure!(
            join.0[port].is_none(),
            "{}: duplicate port {port} for {:?}",
            self.label,
            state
        );
        join.0[port] = Some(t);
        if join.0.iter().all(Option::is_some) {
            let parts: Vec<Tensor> = join.0.into_iter().map(Option::unwrap).collect();
            ctx.stash_bwd(key, Widths(parts.iter().map(|t| t.cols()).collect()))?;
            let refs: Vec<&Tensor> = parts.iter().collect();
            let out = ops::concat_cols(&refs);
            ctx.emit_fwd(0, state, vec![out]);
            Ok(())
        } else {
            ctx.stash(key, join)
        }
    }

    fn backward(
        &mut self,
        _port: PortId,
        state: MsgState,
        payload: Vec<Tensor>,
        ctx: &mut NodeCtx,
    ) -> Result<()> {
        let Widths(widths) = ctx
            .take(state.key())
            .ok_or_else(|| anyhow!("{}: no widths for {:?}", self.label, state))?;
        let parts = ops::split_cols(single(&self.label, &payload)?, &widths);
        for (p, t) in parts.into_iter().enumerate() {
            ctx.emit_bwd(p, state, vec![t]);
        }
        Ok(())
    }

    fn name(&self) -> &str {
        &self.label
    }
}

// ================================================================= Bcast ====

/// Backward gather: cotangent sum over the fan-out. Only the payload
/// shapes are recorded at forward time; the accumulator is built lazily
/// at the first cotangent so nothing payload-sized sits in the stash
/// for the fwd→bwd in-flight window.
struct BcastGather {
    remaining: usize,
    shapes: Vec<Vec<usize>>,
    acc: Option<Vec<Tensor>>,
}

/// Bcast: replicate the forward message to every output port; sum the
/// backward cotangents.
pub struct BcastNode {
    label: String,
    n_out: usize,
}

impl BcastNode {
    pub fn new(label: &str, n_out: usize) -> Self {
        assert!(n_out >= 2);
        BcastNode { label: label.to_string(), n_out }
    }
}

impl Node for BcastNode {
    fn forward(
        &mut self,
        _port: PortId,
        state: MsgState,
        payload: Vec<Tensor>,
        ctx: &mut NodeCtx,
    ) -> Result<()> {
        ctx.stash_bwd(
            state.key(),
            BcastGather {
                remaining: self.n_out,
                shapes: payload.iter().map(|t| t.shape().to_vec()).collect(),
                acc: None,
            },
        )?;
        for p in 0..self.n_out {
            ctx.emit_fwd(p, state, payload.clone());
        }
        Ok(())
    }

    fn backward(
        &mut self,
        _port: PortId,
        state: MsgState,
        payload: Vec<Tensor>,
        ctx: &mut NodeCtx,
    ) -> Result<()> {
        let key = state.key();
        let mut gather = ctx
            .take::<BcastGather>(key)
            .ok_or_else(|| anyhow!("{}: no fwd record for {:?}", self.label, state))?;
        anyhow::ensure!(
            payload.len() == gather.shapes.len(),
            "{}: cotangent arity {} != payload arity {}",
            self.label,
            payload.len(),
            gather.shapes.len()
        );
        let shapes = &gather.shapes;
        let acc = gather
            .acc
            .get_or_insert_with(|| shapes.iter().map(|s| Tensor::zeros(s)).collect());
        for (acc, t) in acc.iter_mut().zip(&payload) {
            acc.axpy(1.0, t);
        }
        gather.remaining -= 1;
        if gather.remaining == 0 {
            ctx.emit_bwd(0, state, gather.acc.unwrap());
            Ok(())
        } else {
            ctx.stash(key, gather)
        }
    }

    fn name(&self) -> &str {
        &self.label
    }
}

// ================================================================= Group ====

/// Join buffer: per-member (state, payload), ordered by the order fn.
struct GroupJoin(Vec<Option<(MsgState, Vec<Tensor>)>>);

/// Member states recorded at the merge for the backward split.
struct Members(Vec<MsgState>);

/// Group: collect `count(state)` single-row messages that share
/// `key(state)` into one batched message; rows ordered by `order(state)`.
/// The merged state is `merge(sample_state, count)`. Backward splits rows
/// and restores the cached member states (§4: "must key on this new state
/// to cache the states of the original messages").
pub struct GroupNode {
    label: String,
    key_fn: KeyFn,
    count_fn: CountFn,
    order_fn: OrderFn,
    merge_fn: MergeFn,
}

impl GroupNode {
    pub fn new(
        label: &str,
        key_fn: KeyFn,
        count_fn: CountFn,
        order_fn: OrderFn,
        merge_fn: MergeFn,
    ) -> Self {
        GroupNode { label: label.to_string(), key_fn, count_fn, order_fn, merge_fn }
    }
}

impl Node for GroupNode {
    fn forward(
        &mut self,
        _port: PortId,
        state: MsgState,
        payload: Vec<Tensor>,
        ctx: &mut NodeCtx,
    ) -> Result<()> {
        let gkey = (self.key_fn)(&state);
        let count = (self.count_fn)(&state);
        anyhow::ensure!(count > 0, "{}: zero group count", self.label);
        let idx = (self.order_fn)(&state);
        anyhow::ensure!(idx < count, "{}: order {idx} >= count {count}", self.label);
        let mut join = ctx.take::<GroupJoin>(gkey).unwrap_or_else(|| {
            let mut v = Vec::with_capacity(count);
            v.resize_with(count, || None);
            GroupJoin(v)
        });
        anyhow::ensure!(join.0[idx].is_none(), "{}: duplicate member {idx}", self.label);
        join.0[idx] = Some((state, payload));
        if join.0.iter().all(Option::is_some) {
            let (states, members): (Vec<MsgState>, Vec<Vec<Tensor>>) =
                join.0.into_iter().map(Option::unwrap).unzip();
            // Stack each payload position across members: [1,D]*N -> [N,D].
            let arity = members[0].len();
            let out: Vec<Tensor> = (0..arity)
                .map(|j| {
                    let refs: Vec<&Tensor> = members.iter().map(|m| &m[j]).collect();
                    ops::stack_rows(&refs)
                })
                .collect();
            let merged = (self.merge_fn)(&states[0], count);
            ctx.stash_bwd(merged.key(), Members(states))?;
            ctx.emit_fwd(0, merged, out);
            Ok(())
        } else {
            ctx.stash(gkey, join)
        }
    }

    fn backward(
        &mut self,
        _port: PortId,
        state: MsgState,
        payload: Vec<Tensor>,
        ctx: &mut NodeCtx,
    ) -> Result<()> {
        let Members(states) = ctx
            .take(state.key())
            .ok_or_else(|| anyhow!("{}: no member record for {:?}", self.label, state))?;
        for d in &payload {
            anyhow::ensure!(d.rows() == states.len(), "{}: cotangent rows", self.label);
        }
        for (i, s) in states.into_iter().enumerate() {
            let row: Vec<Tensor> = payload.iter().map(|d| d.slice_rows(i, 1)).collect();
            ctx.emit_bwd(0, s, row);
        }
        Ok(())
    }

    fn name(&self) -> &str {
        &self.label
    }
}

// =============================================================== Ungroup ====

/// Backward gather keyed by the parent state: member cotangents fill
/// `slots` until all rows are back.
struct UngroupGather {
    pstate: MsgState,
    states: Vec<MsgState>,
    slots: Vec<Option<Vec<Tensor>>>,
}

/// Ungroup: split a batched [N, D] message into N single-row messages
/// with states `states(state)[i]`. Backward collects the N cotangent rows
/// and re-emits the stacked tensor under the original state.
pub struct UngroupNode {
    label: String,
    states_fn: StatesFn,
}

impl UngroupNode {
    pub fn new(label: &str, states_fn: StatesFn) -> Self {
        UngroupNode { label: label.to_string(), states_fn }
    }
}

impl Node for UngroupNode {
    fn forward(
        &mut self,
        _port: PortId,
        state: MsgState,
        payload: Vec<Tensor>,
        ctx: &mut NodeCtx,
    ) -> Result<()> {
        let states = (self.states_fn)(&state);
        for t in &payload {
            anyhow::ensure!(
                states.len() == t.rows(),
                "{}: {} member states for {} rows",
                self.label,
                states.len(),
                t.rows()
            );
        }
        ctx.stash_bwd(
            state.key(),
            UngroupGather {
                pstate: state,
                states: states.clone(),
                slots: {
                    let mut v = Vec::new();
                    v.resize_with(states.len(), || None);
                    v
                },
            },
        )?;
        for (i, s) in states.into_iter().enumerate() {
            let row: Vec<Tensor> = payload.iter().map(|t| t.slice_rows(i, 1)).collect();
            ctx.emit_fwd(0, s, row);
        }
        Ok(())
    }

    fn backward(
        &mut self,
        _port: PortId,
        state: MsgState,
        payload: Vec<Tensor>,
        ctx: &mut NodeCtx,
    ) -> Result<()> {
        // Locate the parent gather this member belongs to (small linear
        // scan: one entry per in-flight group).
        let pkey = ctx
            .find_key::<UngroupGather>(|_, g| {
                g.states.iter().zip(&g.slots).any(|(s, slot)| *s == state && slot.is_none())
            })
            .ok_or_else(|| anyhow!("{}: unmatched backward {:?}", self.label, state))?;
        let mut gather = ctx.take::<UngroupGather>(pkey).unwrap();
        let idx = gather
            .states
            .iter()
            .zip(&gather.slots)
            .position(|(s, slot)| *s == state && slot.is_none())
            .unwrap();
        gather.slots[idx] = Some(payload);
        if gather.slots.iter().all(Option::is_some) {
            let members: Vec<Vec<Tensor>> =
                gather.slots.into_iter().map(Option::unwrap).collect();
            let arity = members[0].len();
            let out: Vec<Tensor> = (0..arity)
                .map(|j| {
                    let refs: Vec<&Tensor> = members.iter().map(|m| &m[j]).collect();
                    ops::stack_rows(&refs)
                })
                .collect();
            ctx.emit_bwd(0, gather.pstate, out);
            Ok(())
        } else {
            ctx.stash(pkey, gather)
        }
    }

    fn name(&self) -> &str {
        &self.label
    }
}

// =============================================================== Flatmap ====

/// Backward gather: cotangent sum over the generated fan-out.
struct FlatmapGather {
    pstate: MsgState,
    states: Vec<MsgState>,
    remaining: usize,
    acc: Vec<Tensor>,
}

/// Flatmap: per incoming message emit one message per generated state,
/// payload replicated. Backward sums the cotangents and restores the
/// original state (§4). If the generator returns zero states (e.g. a
/// graph node with no outgoing edges) the node immediately reflects a
/// zero cotangent backward, preserving the fwd/bwd invariant.
pub struct FlatmapNode {
    label: String,
    states_fn: StatesFn,
}

impl FlatmapNode {
    pub fn new(label: &str, states_fn: StatesFn) -> Self {
        FlatmapNode { label: label.to_string(), states_fn }
    }
}

impl Node for FlatmapNode {
    fn forward(
        &mut self,
        _port: PortId,
        state: MsgState,
        payload: Vec<Tensor>,
        ctx: &mut NodeCtx,
    ) -> Result<()> {
        let states = (self.states_fn)(&state);
        if states.is_empty() {
            // Dead end: zero gradient flows back immediately.
            if ctx.grad_enabled() {
                let zeros = payload.iter().map(|t| Tensor::zeros(t.shape())).collect();
                ctx.emit_bwd(0, state, zeros);
            }
            return Ok(());
        }
        ctx.stash_bwd(
            state.key(),
            FlatmapGather {
                pstate: state,
                states: states.clone(),
                remaining: states.len(),
                acc: payload.iter().map(|t| Tensor::zeros(t.shape())).collect(),
            },
        )?;
        for s in states {
            ctx.emit_fwd(0, s, payload.clone());
        }
        Ok(())
    }

    fn backward(
        &mut self,
        _port: PortId,
        state: MsgState,
        payload: Vec<Tensor>,
        ctx: &mut NodeCtx,
    ) -> Result<()> {
        let pkey = ctx
            .find_key::<FlatmapGather>(|_, f| f.states.iter().any(|s| *s == state))
            .ok_or_else(|| anyhow!("{}: unmatched backward {:?}", self.label, state))?;
        let mut gather = ctx.take::<FlatmapGather>(pkey).unwrap();
        anyhow::ensure!(gather.acc.len() == payload.len(), "{}: arity", self.label);
        for (acc, t) in gather.acc.iter_mut().zip(&payload) {
            acc.axpy(1.0, t);
        }
        gather.remaining -= 1;
        if gather.remaining == 0 {
            ctx.emit_bwd(0, gather.pstate, gather.acc);
            Ok(())
        } else {
            ctx.stash(pkey, gather)
        }
    }

    fn name(&self) -> &str {
        &self.label
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::message::{Dir, Message};
    use crate::ir::rt::{invoke_msg, NodeRt};
    use crate::runtime::NativeBackend;
    use std::sync::mpsc::channel;

    fn drive(
        node: &mut dyn Node,
        rt: &mut NodeRt,
        port: PortId,
        msg: Message,
    ) -> Vec<(PortId, Message)> {
        let (tx, _rx) = channel();
        let mut be = NativeBackend::new();
        invoke_msg(node, rt, &mut be, &tx, 0, port, msg).unwrap()
    }

    fn row(v: &[f32]) -> Tensor {
        Tensor::from_rows(1, v.len(), v.to_vec())
    }

    #[test]
    fn concat_roundtrip() {
        let mut n = ConcatNode::new("cat", 2);
        let mut rt = NodeRt::new();
        let s = MsgState::for_instance(1);
        assert!(drive(&mut n, &mut rt, 0, Message::fwd(s, vec![row(&[1., 2.])])).is_empty());
        let out = drive(&mut n, &mut rt, 1, Message::fwd(s, vec![row(&[3.])]));
        assert_eq!(out[0].1.tensor().data(), &[1., 2., 3.]);
        let back = drive(&mut n, &mut rt, 0, Message::bwd(s, vec![row(&[10., 20., 30.])]));
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].1.tensor().data(), &[10., 20.]);
        assert_eq!(back[1].1.tensor().data(), &[30.]);
        assert_eq!(rt.cached(), 0);
    }

    #[test]
    fn concat_merges_and_echoes_per_port_tags() {
        let mut n = ConcatNode::new("cat", 2);
        let mut rt = NodeRt::new();
        let s = MsgState::for_instance(9);
        drive(&mut n, &mut rt, 0, Message::fwd(s, vec![row(&[1.])]).versioned(3));
        let out = drive(&mut n, &mut rt, 1, Message::fwd(s, vec![row(&[2.])]).versioned(8));
        assert_eq!(out[0].1.version(), Some(8), "join carries the max version");
        assert!(out[0].1.is_train());
        let back = drive(&mut n, &mut rt, 0, Message::bwd(s, vec![row(&[1., 1.])]).versioned(8));
        assert_eq!(back[0].1.version(), Some(3), "port 0 echoes its producer");
        assert_eq!(back[1].1.version(), Some(8), "port 1 echoes its producer");
    }

    #[test]
    fn bcast_sums_cotangents() {
        let mut n = BcastNode::new("bc", 2);
        let mut rt = NodeRt::new();
        let s = MsgState::for_instance(1);
        let f = drive(&mut n, &mut rt, 0, Message::fwd(s, vec![row(&[1., 1.])]));
        assert_eq!(f.len(), 2);
        assert!(drive(&mut n, &mut rt, 0, Message::bwd(s, vec![row(&[1., 2.])])).is_empty());
        let done = drive(&mut n, &mut rt, 1, Message::bwd(s, vec![row(&[10., 20.])]));
        assert_eq!(done[0].1.tensor().data(), &[11., 22.]);
        assert_eq!(rt.cached(), 0);
    }

    fn group_by_instance() -> GroupNode {
        GroupNode::new(
            "grp",
            Box::new(|s| {
                let mut k = *s;
                k.node = 0;
                k.key()
            }),
            Box::new(|s| s.aux as usize),
            Box::new(|s| s.node as usize),
            Box::new(|s, count| {
                let mut m = *s;
                m.node = 0;
                m.aux = count as u32;
                m
            }),
        )
    }

    #[test]
    fn group_orders_members_and_splits_backward() {
        let mut n = group_by_instance();
        let mut rt = NodeRt::new();
        let mut s0 = MsgState::for_instance(1);
        s0.aux = 3;
        let (mut s1, mut s2) = (s0, s0);
        s0.node = 0;
        s1.node = 1;
        s2.node = 2;
        // arrive out of order
        assert!(drive(&mut n, &mut rt, 0, Message::fwd(s2, vec![row(&[2.])])).is_empty());
        assert!(drive(&mut n, &mut rt, 0, Message::fwd(s0, vec![row(&[0.])])).is_empty());
        let out = drive(&mut n, &mut rt, 0, Message::fwd(s1, vec![row(&[1.])]));
        assert_eq!(out[0].1.tensor().data(), &[0., 1., 2.], "ordered by node id");
        let merged = out[0].1.state;
        assert_eq!(merged.aux, 3);
        let back = drive(
            &mut n,
            &mut rt,
            0,
            Message::bwd(merged, vec![Tensor::from_rows(3, 1, vec![5., 6., 7.])]),
        );
        assert_eq!(back.len(), 3);
        assert_eq!(back[0].1.state, s0);
        assert_eq!(back[2].1.tensor().data(), &[7.]);
        assert_eq!(rt.cached(), 0);
    }

    #[test]
    fn group_ungroup_roundtrip_preserves_tags() {
        // Group -> Ungroup: merged fwd tag = max over members; the
        // re-split backward echo restores the merged tag to every member.
        let mut grp = group_by_instance();
        let mut ug = UngroupNode::new(
            "ug",
            Box::new(|s: &MsgState| {
                (0..s.aux)
                    .map(|i| {
                        let mut m = *s;
                        m.node = i;
                        m.aux = 0;
                        m
                    })
                    .collect()
            }),
        );
        let (mut rt_g, mut rt_u) = (NodeRt::new(), NodeRt::new());
        let mut s0 = MsgState::for_instance(2);
        s0.aux = 2;
        let mut s1 = s0;
        s0.node = 0;
        s1.node = 1;
        drive(&mut grp, &mut rt_g, 0, Message::fwd(s0, vec![row(&[0.])]).versioned(2));
        let out =
            drive(&mut grp, &mut rt_g, 0, Message::fwd(s1, vec![row(&[1.])]).versioned(5));
        let merged = out[0].1.state;
        assert_eq!(out[0].1.version(), Some(5), "group merges member tags by max");
        // through Ungroup and back
        let members = drive(&mut ug, &mut rt_u, 0, out[0].1.clone());
        assert_eq!(members.len(), 2);
        assert!(members.iter().all(|(_, m)| m.version() == Some(5)));
        let mut acc = Vec::new();
        for (_, m) in &members {
            let b = Message::bwd(m.state, vec![row(&[1.])]).versioned(5);
            acc = drive(&mut ug, &mut rt_u, 0, b);
        }
        assert_eq!(acc.len(), 1);
        assert_eq!(acc[0].1.state, merged);
        assert_eq!(acc[0].1.version(), Some(5));
        let back = drive(&mut grp, &mut rt_g, 0, acc.remove(0).1);
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].1.version(), Some(5), "members receive the merged echo");
        assert_eq!(rt_g.cached() + rt_u.cached(), 0);
    }

    #[test]
    fn ungroup_roundtrip() {
        let states = |s: &MsgState| {
            (0..3)
                .map(|i| {
                    let mut m = *s;
                    m.node = i as u32 + 10;
                    m
                })
                .collect::<Vec<_>>()
        };
        let mut n = UngroupNode::new("ug", Box::new(states));
        let mut rt = NodeRt::new();
        let s = MsgState::for_instance(4);
        let batch = Tensor::from_rows(3, 2, vec![0., 0., 1., 1., 2., 2.]);
        let out = drive(&mut n, &mut rt, 0, Message::fwd(s, vec![batch]));
        assert_eq!(out.len(), 3);
        assert_eq!(out[1].1.state.node, 11);
        assert_eq!(out[1].1.tensor().data(), &[1., 1.]);
        // send cotangents back out of order
        let mut acc = Vec::new();
        for i in [2usize, 0, 1] {
            let ms = out[i].1.state;
            acc = drive(&mut n, &mut rt, 0, Message::bwd(ms, vec![row(&[i as f32, i as f32])]));
        }
        assert_eq!(acc.len(), 1);
        assert_eq!(acc[0].1.state, s);
        assert_eq!(acc[0].1.tensor().data(), &[0., 0., 1., 1., 2., 2.]);
    }

    #[test]
    fn flatmap_replicates_and_sums() {
        let states = |s: &MsgState| {
            (0..2)
                .map(|i| {
                    let mut m = *s;
                    m.edge = i as u32;
                    m
                })
                .collect::<Vec<_>>()
        };
        let mut n = FlatmapNode::new("fm", Box::new(states));
        let mut rt = NodeRt::new();
        let s = MsgState::for_instance(5);
        let out = drive(&mut n, &mut rt, 0, Message::fwd(s, vec![row(&[7.])]));
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].1.tensor().data(), &[7.]);
        let b0 = drive(&mut n, &mut rt, 0, Message::bwd(out[0].1.state, vec![row(&[1.])]));
        assert!(b0.is_empty());
        let b1 = drive(&mut n, &mut rt, 0, Message::bwd(out[1].1.state, vec![row(&[2.])]));
        assert_eq!(b1[0].1.state, s);
        assert_eq!(b1[0].1.tensor().data(), &[3.], "summed");
    }

    #[test]
    fn flatmap_zero_fanout_reflects_zero_gradient() {
        let mut n = FlatmapNode::new("fm0", Box::new(|_s| Vec::new()));
        let mut rt = NodeRt::new();
        let s = MsgState::for_instance(6);
        let out = drive(&mut n, &mut rt, 0, Message::fwd(s, vec![row(&[1., 2.])]));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].1.dir, Dir::Bwd);
        assert_eq!(out[0].1.tensor().data(), &[0., 0.]);
        assert_eq!(rt.cached(), 0);
    }
}
