//! (Dis-)aggregation combinators: Concat, Bcast, Group, Ungroup, Flatmap
//! (§4 Fig. 3). These recover forms of batching inside the streaming
//! runtime — e.g. the GGSNN groups all edges of one type into a single
//! batched linear-layer message.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::ir::graph::{Node, NodeCtx, PortId};
use crate::ir::message::Message;
use crate::ir::state::{MsgState, StateKey};
use crate::tensor::{ops, Tensor};

pub type KeyFn = Box<dyn Fn(&MsgState) -> StateKey + Send>;
pub type CountFn = Box<dyn Fn(&MsgState) -> usize + Send>;
pub type OrderFn = Box<dyn Fn(&MsgState) -> usize + Send>;
pub type MergeFn = Box<dyn Fn(&MsgState, usize) -> MsgState + Send>;
pub type StatesFn = Box<dyn Fn(&MsgState) -> Vec<MsgState> + Send>;

// ================================================================ Concat ====

/// Concat: join one message per input port (same state) into a single
/// message whose tensor is the column-concatenation. Backward splits the
/// cotangent by the recorded widths. Used for `[embedding, h]` in the RNN.
pub struct ConcatNode {
    label: String,
    n_in: usize,
    pending: HashMap<StateKey, Vec<Option<Tensor>>>,
    widths: HashMap<StateKey, Vec<usize>>,
}

impl ConcatNode {
    pub fn new(label: &str, n_in: usize) -> Self {
        assert!(n_in >= 2);
        ConcatNode {
            label: label.to_string(),
            n_in,
            pending: HashMap::new(),
            widths: HashMap::new(),
        }
    }
}

impl Node for ConcatNode {
    fn forward(&mut self, port: PortId, msg: Message, _ctx: &mut NodeCtx) -> Result<Vec<(PortId, Message)>> {
        anyhow::ensure!(port < self.n_in, "{}: bad port {port}", self.label);
        let key = msg.state.key();
        let n_in = self.n_in;
        let slot = self.pending.entry(key).or_insert_with(|| vec![None; n_in]);
        anyhow::ensure!(slot[port].is_none(), "{}: duplicate port {port} for {:?}", self.label, msg.state);
        slot[port] = Some(msg.tensor().clone());
        if slot.iter().all(Option::is_some) {
            let parts: Vec<Tensor> =
                self.pending.remove(&key).unwrap().into_iter().map(Option::unwrap).collect();
            if msg.train {
                self.widths.insert(key, parts.iter().map(|t| t.cols()).collect());
            }
            let refs: Vec<&Tensor> = parts.iter().collect();
            let out = ops::concat_cols(&refs);
            let mut m = Message::fwd(msg.state, vec![out]);
            m.train = msg.train;
            Ok(vec![(0, m)])
        } else {
            Ok(Vec::new())
        }
    }

    fn backward(&mut self, _port: PortId, msg: Message, _ctx: &mut NodeCtx) -> Result<Vec<(PortId, Message)>> {
        let widths = self
            .widths
            .remove(&msg.state.key())
            .ok_or_else(|| anyhow!("{}: no widths for {:?}", self.label, msg.state))?;
        let parts = ops::split_cols(msg.tensor(), &widths);
        Ok(parts
            .into_iter()
            .enumerate()
            .map(|(p, t)| (p, Message::bwd(msg.state, vec![t])))
            .collect())
    }

    fn cached_keys(&self) -> usize {
        self.pending.len() + self.widths.len()
    }

    fn name(&self) -> &str {
        &self.label
    }
}

// ================================================================= Bcast ====

/// Bcast: replicate the forward message to every output port; sum the
/// backward cotangents. Output arities may differ (e.g. the tree head
/// consumes only h while the parent consumes (h,c)): missing positions
/// are treated as zero.
pub struct BcastNode {
    label: String,
    n_out: usize,
    pending: HashMap<StateKey, (usize, Vec<Tensor>)>,
    /// Payload arity of the input (recorded forward, used to assemble bwd).
    arities: HashMap<StateKey, Vec<Vec<usize>>>,
}

impl BcastNode {
    pub fn new(label: &str, n_out: usize) -> Self {
        assert!(n_out >= 2);
        BcastNode { label: label.to_string(), n_out, pending: HashMap::new(), arities: HashMap::new() }
    }
}

impl Node for BcastNode {
    fn forward(&mut self, _port: PortId, msg: Message, _ctx: &mut NodeCtx) -> Result<Vec<(PortId, Message)>> {
        if msg.train {
            self.arities.insert(
                msg.state.key(),
                msg.payload.iter().map(|t| t.shape().to_vec()).collect(),
            );
        }
        Ok((0..self.n_out)
            .map(|p| {
                let mut m = Message::fwd(msg.state, msg.payload.clone());
                m.train = msg.train;
                (p, m)
            })
            .collect())
    }

    fn backward(&mut self, _port: PortId, msg: Message, _ctx: &mut NodeCtx) -> Result<Vec<(PortId, Message)>> {
        let key = msg.state.key();
        let shapes = self
            .arities
            .get(&key)
            .ok_or_else(|| anyhow!("{}: no fwd record for {:?}", self.label, msg.state))?
            .clone();
        let entry = self.pending.entry(key).or_insert_with(|| {
            (0, shapes.iter().map(|s| Tensor::zeros(s)).collect())
        });
        // Cotangents may cover a prefix of the payload (consumer selected
        // a subset via SelectNode, which pads back) — require full arity.
        anyhow::ensure!(
            msg.payload.len() == entry.1.len(),
            "{}: cotangent arity {} != payload arity {}",
            self.label,
            msg.payload.len(),
            entry.1.len()
        );
        for (acc, t) in entry.1.iter_mut().zip(&msg.payload) {
            acc.axpy(1.0, t);
        }
        entry.0 += 1;
        if entry.0 == self.n_out {
            let (_, sum) = self.pending.remove(&key).unwrap();
            self.arities.remove(&key);
            Ok(vec![(0, Message::bwd(msg.state, sum))])
        } else {
            Ok(Vec::new())
        }
    }

    fn cached_keys(&self) -> usize {
        self.pending.len() + self.arities.len()
    }

    fn name(&self) -> &str {
        &self.label
    }
}

// ================================================================= Group ====

/// Group: collect `count(state)` single-row messages that share
/// `key(state)` into one batched message; rows ordered by `order(state)`.
/// The merged state is `merge(sample_state, count)`. Backward splits rows
/// and restores the cached member states (§4: "must key on this new state
/// to cache the states of the original messages").
pub struct GroupNode {
    label: String,
    key_fn: KeyFn,
    count_fn: CountFn,
    order_fn: OrderFn,
    merge_fn: MergeFn,
    pending: HashMap<StateKey, Vec<Option<(MsgState, Vec<Tensor>)>>>,
    members: HashMap<StateKey, Vec<MsgState>>,
}

impl GroupNode {
    pub fn new(label: &str, key_fn: KeyFn, count_fn: CountFn, order_fn: OrderFn, merge_fn: MergeFn) -> Self {
        GroupNode {
            label: label.to_string(),
            key_fn,
            count_fn,
            order_fn,
            merge_fn,
            pending: HashMap::new(),
            members: HashMap::new(),
        }
    }
}

impl Node for GroupNode {
    fn forward(&mut self, _port: PortId, msg: Message, _ctx: &mut NodeCtx) -> Result<Vec<(PortId, Message)>> {
        let gkey = (self.key_fn)(&msg.state);
        let count = (self.count_fn)(&msg.state);
        anyhow::ensure!(count > 0, "{}: zero group count", self.label);
        let idx = (self.order_fn)(&msg.state);
        anyhow::ensure!(idx < count, "{}: order {idx} >= count {count}", self.label);
        let slot = self.pending.entry(gkey).or_insert_with(|| {
            let mut v = Vec::with_capacity(count);
            v.resize_with(count, || None);
            v
        });
        anyhow::ensure!(slot[idx].is_none(), "{}: duplicate member {idx}", self.label);
        slot[idx] = Some((msg.state, msg.payload));
        if slot.iter().all(Option::is_some) {
            let filled = self.pending.remove(&gkey).unwrap();
            let (states, members): (Vec<MsgState>, Vec<Vec<Tensor>>) =
                filled.into_iter().map(Option::unwrap).unzip();
            // Stack each payload position across members: [1,D]*N -> [N,D].
            let arity = members[0].len();
            let out: Vec<Tensor> = (0..arity)
                .map(|j| {
                    let refs: Vec<&Tensor> = members.iter().map(|m| &m[j]).collect();
                    ops::stack_rows(&refs)
                })
                .collect();
            let merged = (self.merge_fn)(&states[0], count);
            if msg.train {
                self.members.insert(merged.key(), states);
            }
            let mut m = Message::fwd(merged, out);
            m.train = msg.train;
            Ok(vec![(0, m)])
        } else {
            Ok(Vec::new())
        }
    }

    fn backward(&mut self, _port: PortId, msg: Message, _ctx: &mut NodeCtx) -> Result<Vec<(PortId, Message)>> {
        let states = self
            .members
            .remove(&msg.state.key())
            .ok_or_else(|| anyhow!("{}: no member record for {:?}", self.label, msg.state))?;
        for d in &msg.payload {
            anyhow::ensure!(d.rows() == states.len(), "{}: cotangent rows", self.label);
        }
        Ok(states
            .into_iter()
            .enumerate()
            .map(|(i, s)| {
                let row: Vec<Tensor> = msg.payload.iter().map(|d| d.slice_rows(i, 1)).collect();
                (0, Message::bwd(s, row))
            })
            .collect())
    }

    fn cached_keys(&self) -> usize {
        self.pending.len() + self.members.len()
    }

    fn name(&self) -> &str {
        &self.label
    }
}

// =============================================================== Ungroup ====

/// Ungroup: split a batched [N, D] message into N single-row messages
/// with states `states(state)[i]`. Backward collects the N cotangent rows
/// and re-emits the stacked tensor under the original state.
pub struct UngroupNode {
    label: String,
    states_fn: StatesFn,
    pending: HashMap<StateKey, (MsgState, usize, Vec<Option<Vec<Tensor>>>)>,
}

impl UngroupNode {
    pub fn new(label: &str, states_fn: StatesFn) -> Self {
        UngroupNode { label: label.to_string(), states_fn, pending: HashMap::new() }
    }
}

impl Node for UngroupNode {
    fn forward(&mut self, _port: PortId, msg: Message, _ctx: &mut NodeCtx) -> Result<Vec<(PortId, Message)>> {
        let states = (self.states_fn)(&msg.state);
        for t in &msg.payload {
            anyhow::ensure!(
                states.len() == t.rows(),
                "{}: {} member states for {} rows",
                self.label,
                states.len(),
                t.rows()
            );
        }
        if msg.train {
            self.pending.insert(
                msg.state.key(),
                (msg.state, states.len(), {
                    let mut v = Vec::new();
                    v.resize_with(states.len(), || None);
                    v
                }),
            );
        }
        Ok(states
            .into_iter()
            .enumerate()
            .map(|(i, s)| {
                let row: Vec<Tensor> = msg.payload.iter().map(|t| t.slice_rows(i, 1)).collect();
                let mut m = Message::fwd(s, row);
                m.train = msg.train;
                (0, m)
            })
            .collect())
    }

    fn backward(&mut self, _port: PortId, msg: Message, _ctx: &mut NodeCtx) -> Result<Vec<(PortId, Message)>> {
        // Identify which parent this row belongs to by regenerating states.
        // The backward message carries the member state; we find its parent
        // by scanning pending groups (small: one per in-flight group key).
        let mut found: Option<(StateKey, usize)> = None;
        for (pkey, (pstate, _n, slots)) in self.pending.iter() {
            let states = (self.states_fn)(pstate);
            if let Some(i) = states.iter().position(|s| *s == msg.state) {
                if slots[i].is_none() {
                    found = Some((*pkey, i));
                    break;
                }
            }
        }
        let (pkey, idx) = found
            .ok_or_else(|| anyhow!("{}: unmatched backward {:?}", self.label, msg.state))?;
        let entry = self.pending.get_mut(&pkey).unwrap();
        entry.2[idx] = Some(msg.payload);
        if entry.2.iter().all(Option::is_some) {
            let (pstate, _, slots) = self.pending.remove(&pkey).unwrap();
            let members: Vec<Vec<Tensor>> = slots.into_iter().map(Option::unwrap).collect();
            let arity = members[0].len();
            let out: Vec<Tensor> = (0..arity)
                .map(|j| {
                    let refs: Vec<&Tensor> = members.iter().map(|m| &m[j]).collect();
                    ops::stack_rows(&refs)
                })
                .collect();
            Ok(vec![(0, Message::bwd(pstate, out))])
        } else {
            Ok(Vec::new())
        }
    }

    fn cached_keys(&self) -> usize {
        self.pending.len()
    }

    fn name(&self) -> &str {
        &self.label
    }
}

// =============================================================== Flatmap ====

/// Flatmap: per incoming message emit one message per generated state,
/// payload replicated. Backward sums the cotangents and restores the
/// original state (§4). If the generator returns zero states (e.g. a
/// graph node with no outgoing edges) the node immediately reflects a
/// zero cotangent backward, preserving the fwd/bwd invariant.
pub struct FlatmapNode {
    label: String,
    states_fn: StatesFn,
    pending: HashMap<StateKey, (MsgState, usize, Vec<Tensor>)>,
}

impl FlatmapNode {
    pub fn new(label: &str, states_fn: StatesFn) -> Self {
        FlatmapNode { label: label.to_string(), states_fn, pending: HashMap::new() }
    }
}

impl Node for FlatmapNode {
    fn forward(&mut self, _port: PortId, msg: Message, _ctx: &mut NodeCtx) -> Result<Vec<(PortId, Message)>> {
        let states = (self.states_fn)(&msg.state);
        if states.is_empty() {
            // Dead end: zero gradient flows back immediately.
            if msg.train {
                let zeros = msg.payload.iter().map(|t| Tensor::zeros(t.shape())).collect();
                return Ok(vec![(0, Message::bwd(msg.state, zeros))]);
            }
            return Ok(Vec::new());
        }
        if msg.train {
            // Index members by their generated state; cache count + shapes.
            self.pending.insert(
                msg.state.key(),
                (
                    msg.state,
                    states.len(),
                    msg.payload.iter().map(|t| Tensor::zeros(t.shape())).collect(),
                ),
            );
        }
        Ok(states
            .into_iter()
            .map(|s| {
                let mut m = Message::fwd(s, msg.payload.clone());
                m.train = msg.train;
                (0, m)
            })
            .collect())
    }

    fn backward(&mut self, _port: PortId, msg: Message, _ctx: &mut NodeCtx) -> Result<Vec<(PortId, Message)>> {
        // Find parent by regenerating (as in Ungroup).
        let mut parent: Option<StateKey> = None;
        for (pkey, (pstate, _n, _acc)) in self.pending.iter() {
            if (self.states_fn)(pstate).iter().any(|s| *s == msg.state) {
                parent = Some(*pkey);
                break;
            }
        }
        let pkey = parent
            .ok_or_else(|| anyhow!("{}: unmatched backward {:?}", self.label, msg.state))?;
        let entry = self.pending.get_mut(&pkey).unwrap();
        anyhow::ensure!(entry.2.len() == msg.payload.len(), "{}: arity", self.label);
        for (acc, t) in entry.2.iter_mut().zip(&msg.payload) {
            acc.axpy(1.0, t);
        }
        entry.1 -= 1;
        if entry.1 == 0 {
            let (pstate, _, acc) = self.pending.remove(&pkey).unwrap();
            Ok(vec![(0, Message::bwd(pstate, acc))])
        } else {
            Ok(Vec::new())
        }
    }

    fn cached_keys(&self) -> usize {
        self.pending.len()
    }

    fn name(&self) -> &str {
        &self.label
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::graph::Event;
    use crate::runtime::NativeBackend;
    use std::sync::mpsc::channel;

    fn mkctx<'a>(be: &'a mut NativeBackend, tx: &'a std::sync::mpsc::Sender<Event>) -> NodeCtx<'a> {
        NodeCtx { backend: be, events: tx, node_id: 0 }
    }

    fn row(v: &[f32]) -> Tensor {
        Tensor::from_rows(1, v.len(), v.to_vec())
    }

    #[test]
    fn concat_roundtrip() {
        let mut n = ConcatNode::new("cat", 2);
        let (tx, _rx) = channel();
        let mut be = NativeBackend::new();
        let mut c = mkctx(&mut be, &tx);
        let s = MsgState::for_instance(1);
        assert!(n.forward(0, Message::fwd(s, vec![row(&[1., 2.])]), &mut c).unwrap().is_empty());
        let out = n.forward(1, Message::fwd(s, vec![row(&[3.])]), &mut c).unwrap();
        assert_eq!(out[0].1.tensor().data(), &[1., 2., 3.]);
        let back = n.backward(0, Message::bwd(s, vec![row(&[10., 20., 30.])]), &mut c).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].1.tensor().data(), &[10., 20.]);
        assert_eq!(back[1].1.tensor().data(), &[30.]);
        assert_eq!(n.cached_keys(), 0);
    }

    #[test]
    fn bcast_sums_cotangents() {
        let mut n = BcastNode::new("bc", 2);
        let (tx, _rx) = channel();
        let mut be = NativeBackend::new();
        let mut c = mkctx(&mut be, &tx);
        let s = MsgState::for_instance(1);
        let f = n.forward(0, Message::fwd(s, vec![row(&[1., 1.])]), &mut c).unwrap();
        assert_eq!(f.len(), 2);
        assert!(n.backward(0, Message::bwd(s, vec![row(&[1., 2.])]), &mut c).unwrap().is_empty());
        let done = n.backward(1, Message::bwd(s, vec![row(&[10., 20.])]), &mut c).unwrap();
        assert_eq!(done[0].1.tensor().data(), &[11., 22.]);
        assert_eq!(n.cached_keys(), 0);
    }

    fn group_by_instance() -> GroupNode {
        GroupNode::new(
            "grp",
            Box::new(|s| {
                let mut k = *s;
                k.node = 0;
                k.key()
            }),
            Box::new(|s| s.aux as usize),
            Box::new(|s| s.node as usize),
            Box::new(|s, count| {
                let mut m = *s;
                m.node = 0;
                m.aux = count as u32;
                m
            }),
        )
    }

    #[test]
    fn group_orders_members_and_splits_backward() {
        let mut n = group_by_instance();
        let (tx, _rx) = channel();
        let mut be = NativeBackend::new();
        let mut c = mkctx(&mut be, &tx);
        let mut s0 = MsgState::for_instance(1);
        s0.aux = 3;
        let (mut s1, mut s2) = (s0, s0);
        s0.node = 0;
        s1.node = 1;
        s2.node = 2;
        // arrive out of order
        assert!(n.forward(0, Message::fwd(s2, vec![row(&[2.])]), &mut c).unwrap().is_empty());
        assert!(n.forward(0, Message::fwd(s0, vec![row(&[0.])]), &mut c).unwrap().is_empty());
        let out = n.forward(0, Message::fwd(s1, vec![row(&[1.])]), &mut c).unwrap();
        assert_eq!(out[0].1.tensor().data(), &[0., 1., 2.], "ordered by node id");
        let merged = out[0].1.state;
        assert_eq!(merged.aux, 3);
        let back = n
            .backward(0, Message::bwd(merged, vec![Tensor::from_rows(3, 1, vec![5., 6., 7.])]), &mut c)
            .unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back[0].1.state, s0);
        assert_eq!(back[2].1.tensor().data(), &[7.]);
        assert_eq!(n.cached_keys(), 0);
    }

    #[test]
    fn ungroup_roundtrip() {
        let states = |s: &MsgState| {
            (0..3)
                .map(|i| {
                    let mut m = *s;
                    m.node = i as u32 + 10;
                    m
                })
                .collect::<Vec<_>>()
        };
        let mut n = UngroupNode::new("ug", Box::new(states));
        let (tx, _rx) = channel();
        let mut be = NativeBackend::new();
        let mut c = mkctx(&mut be, &tx);
        let s = MsgState::for_instance(4);
        let batch = Tensor::from_rows(3, 2, vec![0., 0., 1., 1., 2., 2.]);
        let out = n.forward(0, Message::fwd(s, vec![batch]), &mut c).unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[1].1.state.node, 11);
        assert_eq!(out[1].1.tensor().data(), &[1., 1.]);
        // send cotangents back out of order
        let mut acc = Vec::new();
        for i in [2usize, 0, 1] {
            let ms = out[i].1.state;
            acc = n.backward(0, Message::bwd(ms, vec![row(&[i as f32, i as f32])]), &mut c).unwrap();
        }
        assert_eq!(acc.len(), 1);
        assert_eq!(acc[0].1.state, s);
        assert_eq!(acc[0].1.tensor().data(), &[0., 0., 1., 1., 2., 2.]);
    }

    #[test]
    fn flatmap_replicates_and_sums() {
        let states = |s: &MsgState| {
            (0..2)
                .map(|i| {
                    let mut m = *s;
                    m.edge = i as u32;
                    m
                })
                .collect::<Vec<_>>()
        };
        let mut n = FlatmapNode::new("fm", Box::new(states));
        let (tx, _rx) = channel();
        let mut be = NativeBackend::new();
        let mut c = mkctx(&mut be, &tx);
        let s = MsgState::for_instance(5);
        let out = n.forward(0, Message::fwd(s, vec![row(&[7.])]), &mut c).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].1.tensor().data(), &[7.]);
        let b0 = n.backward(0, Message::bwd(out[0].1.state, vec![row(&[1.])]), &mut c).unwrap();
        assert!(b0.is_empty());
        let b1 = n.backward(0, Message::bwd(out[1].1.state, vec![row(&[2.])]), &mut c).unwrap();
        assert_eq!(b1[0].1.state, s);
        assert_eq!(b1[0].1.tensor().data(), &[3.], "summed");
    }

    #[test]
    fn flatmap_zero_fanout_reflects_zero_gradient() {
        let mut n = FlatmapNode::new("fm0", Box::new(|_s| Vec::new()));
        let (tx, _rx) = channel();
        let mut be = NativeBackend::new();
        let mut c = mkctx(&mut be, &tx);
        let s = MsgState::for_instance(6);
        let out = n.forward(0, Message::fwd(s, vec![row(&[1., 2.])]), &mut c).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].1.dir, crate::ir::message::Dir::Bwd);
        assert_eq!(out[0].1.tensor().data(), &[0., 0.]);
    }
}
