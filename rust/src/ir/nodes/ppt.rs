//! Parameterized payload transform (PPT) — the workhorse IR node.
//!
//! A PPT node wraps a (fwd, bwd) artifact pair plus a local [`ParamSet`].
//! Forward: join data inputs across its input ports (keyed by message
//! state), pad the batch to an allowed bucket, execute the fwd artifact,
//! cache the (unpadded) inputs keyed by state — "an activation is recorded
//! by keying on the state of the message" (§4) — and emit the outputs.
//! Backward: replay the cached inputs through the bwd artifact, route the
//! input cotangents back per port, and accumulate parameter gradients,
//! applying a local update whenever `min_update_frequency` rows have been
//! seen (§3).

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::ir::graph::{Event, Node, NodeCtx, PortId};
use crate::ir::message::Message;
use crate::ir::state::{MsgState, StateKey};
use crate::optim::{Optimizer, ParamSet};
use crate::runtime::{artifact_name, KernelFlavor};
use crate::tensor::Tensor;
use crate::util::stats::bucket_for;

/// Configuration of a PPT node.
pub struct PptConfig {
    /// Artifact op stem, e.g. "linear_relu" (expands to `<op>_fwd`/`<op>_bwd`).
    pub op: String,
    /// Which lowering of the op to execute.
    pub flavor: KernelFlavor,
    /// Artifact dims *excluding* the batch dim `b`, e.g. [("i",784),("o",784)].
    pub dims: Vec<(String, usize)>,
    /// Allowed batch buckets (ascending). Payload rows are zero-padded up
    /// to the nearest bucket; single-bucket models use `vec![B]`.
    pub buckets: Vec<usize>,
    /// Payload tensors expected per input port (e.g. branch LSTM: [2, 2]).
    pub in_port_arity: Vec<usize>,
    /// How many outputs the fwd artifact produces (all flow out of port 0
    /// as one message).
    pub n_outputs: usize,
    /// Multi-port join key ("a Phi/PPT node must be parameterized over
    /// the keying function on the state", §4). Default: the full state.
    /// The tree-LSTM branch cell keys on (instance, node) so that left
    /// and right child messages — which differ in `edge` — meet.
    pub join_key: Option<Box<dyn Fn(&MsgState) -> StateKey + Send>>,
    /// State of the emitted output message (default: the state of the
    /// port-0 input). The branch cell canonicalizes `edge = 0` here.
    pub out_state: Option<Box<dyn Fn(&MsgState) -> MsgState + Send>>,
}

impl PptConfig {
    /// Common case: 1 input port, 1 payload tensor, 1 output.
    pub fn simple(
        op: &str,
        flavor: KernelFlavor,
        dims: &[(&str, usize)],
        buckets: Vec<usize>,
    ) -> Self {
        PptConfig {
            op: op.to_string(),
            flavor,
            dims: dims.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
            buckets,
            in_port_arity: vec![1],
            n_outputs: 1,
            join_key: None,
            out_state: None,
        }
    }
}

struct PendingJoin {
    /// Per-port (state, producer version tag, payload), filled as
    /// messages arrive.
    ports: Vec<Option<(MsgState, Option<u64>, Vec<Tensor>)>>,
    train: bool,
}

struct FwdCache {
    /// Data inputs in artifact order (unpadded).
    data_inputs: Vec<Tensor>,
    /// Original per-port input states (backward messages restore these).
    port_states: Vec<MsgState>,
    /// Per-port producer version tags, echoed onto the backward
    /// cotangents so each upstream node receives *its own* version at
    /// forward time (the staleness wire protocol, DESIGN.md §9).
    port_versions: Vec<Option<u64>>,
    /// This node's update counter at forward time (fallback staleness
    /// source when the backward message arrives untagged).
    updates_at_fwd: u64,
}

pub struct PptNode {
    label: String,
    cfg: PptConfig,
    pub params: ParamSet,
    /// Join buffer: waiting for all input ports of a key.
    joins: HashMap<StateKey, PendingJoin>,
    /// Activation cache for the backward pass (train only).
    cache: HashMap<StateKey, FwdCache>,
}

impl PptNode {
    pub fn new(
        label: &str,
        cfg: PptConfig,
        params: Vec<Tensor>,
        opt: Optimizer,
        min_update_frequency: usize,
    ) -> Self {
        assert!(!cfg.buckets.is_empty(), "{label}: empty buckets");
        assert!(!cfg.in_port_arity.is_empty());
        PptNode {
            label: label.to_string(),
            cfg,
            params: ParamSet::new(params, opt, min_update_frequency),
            joins: HashMap::new(),
            cache: HashMap::new(),
        }
    }

    fn art(&self, which: &str, bucket: usize) -> String {
        let mut dims: Vec<(&str, usize)> =
            self.cfg.dims.iter().map(|(k, v)| (k.as_str(), *v)).collect();
        dims.push(("b", bucket));
        artifact_name(&format!("{}_{which}", self.cfg.op), &dims, self.cfg.flavor.as_str())
    }

    fn n_ports(&self) -> usize {
        self.cfg.in_port_arity.len()
    }

    /// Execute the forward artifact over joined inputs.
    fn run_forward(
        &mut self,
        port_states: Vec<MsgState>,
        port_versions: Vec<Option<u64>>,
        data_inputs: Vec<Tensor>,
        train: bool,
        ctx: &mut NodeCtx,
    ) -> Result<Vec<(PortId, Message)>> {
        let out_state = match &self.cfg.out_state {
            Some(f) => f(&port_states[0]),
            None => port_states[0],
        };
        let rows = data_inputs[0].rows();
        let bucket = bucket_for(rows, &self.cfg.buckets);
        let mut args: Vec<Tensor> =
            data_inputs.iter().map(|t| t.pad_rows(bucket)).collect();
        args.extend(self.params.params().iter().cloned());
        let name = self.art("fwd", bucket);
        let outs = ctx.backend.execute(&name, &args)?;
        let outs: Vec<Tensor> = outs
            .into_iter()
            .map(|t| if t.rows() > rows { t.slice_rows(0, rows) } else { t })
            .collect();
        let version = self.params.updates;
        if train {
            self.cache.insert(
                out_state.key(),
                FwdCache { data_inputs, port_states, port_versions, updates_at_fwd: version },
            );
        }
        let mut msg = Message::fwd(out_state, outs).versioned(version);
        msg.train = train;
        Ok(vec![(0, msg)])
    }
}

impl Node for PptNode {
    fn forward(
        &mut self,
        port: PortId,
        msg: Message,
        ctx: &mut NodeCtx,
    ) -> Result<Vec<(PortId, Message)>> {
        anyhow::ensure!(port < self.n_ports(), "{}: bad input port {port}", self.label);
        anyhow::ensure!(
            msg.payload.len() == self.cfg.in_port_arity[port],
            "{}: port {port} expects {} tensors, got {}",
            self.label,
            self.cfg.in_port_arity[port],
            msg.payload.len()
        );
        if self.n_ports() == 1 {
            return self.run_forward(
                vec![msg.state],
                vec![msg.param_version],
                msg.payload,
                msg.train,
                ctx,
            );
        }
        // Multi-port join, keyed by the configured keying function (§4).
        let key = match &self.cfg.join_key {
            Some(f) => f(&msg.state),
            None => msg.state.key(),
        };
        let n_ports = self.n_ports();
        let entry = self.joins.entry(key).or_insert_with(|| PendingJoin {
            ports: (0..n_ports).map(|_| None).collect(),
            train: msg.train,
        });
        anyhow::ensure!(
            entry.ports[port].is_none(),
            "{}: duplicate join on port {port}",
            self.label
        );
        entry.ports[port] = Some((msg.state, msg.param_version, msg.payload));
        if entry.ports.iter().all(Option::is_some) {
            let join = self.joins.remove(&key).unwrap();
            let mut data = Vec::new();
            let mut states = Vec::with_capacity(n_ports);
            let mut versions = Vec::with_capacity(n_ports);
            for p in join.ports {
                let (s, ver, payload) = p.unwrap();
                states.push(s);
                versions.push(ver);
                data.extend(payload);
            }
            self.run_forward(states, versions, data, join.train, ctx)
        } else {
            Ok(Vec::new())
        }
    }

    fn backward(
        &mut self,
        _port: PortId,
        msg: Message,
        ctx: &mut NodeCtx,
    ) -> Result<Vec<(PortId, Message)>> {
        anyhow::ensure!(
            msg.payload.len() == self.cfg.n_outputs,
            "{}: backward expects {} cotangents, got {}",
            self.label,
            self.cfg.n_outputs,
            msg.payload.len()
        );
        let key = msg.state.key();
        let cached = self
            .cache
            .remove(&key)
            .ok_or_else(|| anyhow!("{}: no cached activation for {:?}", self.label, msg.state))?;
        let rows = cached.data_inputs[0].rows();
        let bucket = bucket_for(rows, &self.cfg.buckets);
        let mut args: Vec<Tensor> =
            cached.data_inputs.iter().map(|t| t.pad_rows(bucket)).collect();
        args.extend(self.params.params().iter().cloned());
        args.extend(msg.payload.iter().map(|t| t.pad_rows(bucket)));
        let name = self.art("bwd", bucket);
        let outs = ctx.backend.execute(&name, &args)?;
        let n_data: usize = self.cfg.in_port_arity.iter().sum();
        anyhow::ensure!(
            outs.len() == n_data + self.params.params().len(),
            "{}: bwd artifact arity mismatch ({} vs {})",
            self.label,
            outs.len(),
            n_data + self.params.params().len()
        );
        // Parameter gradients: accumulate locally; update when ready (§3).
        // Staleness is the version delta carried by the backward tag
        // (the forward output's version, echoed back by the consumer);
        // untagged traffic falls back to the cached forward-time counter.
        let version_at_fwd = msg.param_version.unwrap_or(cached.updates_at_fwd);
        let staleness = self.params.updates.saturating_sub(version_at_fwd);
        self.params.accumulate_stale(&outs[n_data..], rows, staleness);
        if self.params.maybe_update() {
            ctx.emit(Event::update(ctx.node_id, self.params.take_staleness_stats()));
        }
        // Input cotangents: slice padding away, split per port, restoring
        // each port's original input state and echoing the producer's
        // version tag so upstream staleness is measured against *its*
        // parameters.
        let mut routes = Vec::with_capacity(self.n_ports());
        let mut idx = 0;
        for (port, &arity) in self.cfg.in_port_arity.iter().enumerate() {
            let tensors: Vec<Tensor> = outs[idx..idx + arity]
                .iter()
                .map(|t| if t.rows() > rows { t.slice_rows(0, rows) } else { t.clone() })
                .collect();
            idx += arity;
            let mut m = Message::bwd(cached.port_states[port], tensors);
            m.param_version = cached.port_versions[port];
            routes.push((port, m));
        }
        Ok(routes)
    }

    fn params(&self) -> Vec<Tensor> {
        self.params.params().to_vec()
    }

    fn set_params(&mut self, params: Vec<Tensor>) {
        self.params.set_params(params);
    }

    fn flush(&mut self, ctx: &mut NodeCtx) -> Result<()> {
        if self.params.pending > 0 && self.params.update() {
            ctx.emit(Event::update(ctx.node_id, self.params.take_staleness_stats()));
        }
        Ok(())
    }

    fn opt_state(&self) -> Option<crate::optim::OptState> {
        Some(self.params.opt_state())
    }

    fn set_opt_state(&mut self, state: crate::optim::OptState) -> Result<()> {
        self.params.set_opt_state(state)
    }

    fn cached_keys(&self) -> usize {
        self.cache.len() + self.joins.len()
    }

    fn name(&self) -> &str {
        &self.label
    }
}

/// Glorot-uniform initialization for a [fan_in, fan_out] weight matrix.
pub fn glorot(rng: &mut crate::util::Pcg32, fan_in: usize, fan_out: usize) -> Tensor {
    let limit = (6.0 / (fan_in + fan_out) as f32).sqrt();
    Tensor::new(
        vec![fan_in, fan_out],
        (0..fan_in * fan_out).map(|_| rng.range(-limit, limit)).collect(),
    )
}

/// Linear-layer parameter pair (glorot W, zero b).
pub fn linear_params(rng: &mut crate::util::Pcg32, i: usize, o: usize) -> Vec<Tensor> {
    vec![glorot(rng, i, o), Tensor::zeros(&[o])]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::graph::Event;
    use crate::runtime::NativeBackend;
    use crate::util::Pcg32;
    use std::sync::mpsc::channel;

    type CtxPair =
        (NativeBackend, std::sync::mpsc::Sender<Event>, std::sync::mpsc::Receiver<Event>);

    fn ctx_pair() -> CtxPair {
        let (tx, rx) = channel();
        (NativeBackend::new(), tx, rx)
    }

    fn linear_ppt(muf: usize, buckets: Vec<usize>) -> PptNode {
        let mut rng = Pcg32::seeded(7);
        PptNode::new(
            "lin",
            PptConfig::simple("linear", KernelFlavor::Xla, &[("i", 4), ("o", 3)], buckets),
            linear_params(&mut rng, 4, 3),
            Optimizer::sgd(0.1),
            muf,
        )
    }

    #[test]
    fn forward_then_backward_roundtrip_updates_params() {
        let (mut be, tx, rx) = ctx_pair();
        let mut node = linear_ppt(1, vec![2]);
        let mut ctx = NodeCtx { backend: &mut be, events: &tx, node_id: 0 };
        let s = MsgState::for_instance(1);
        let x = Tensor::from_rows(2, 4, vec![0.5; 8]);
        let out = node.forward(0, Message::fwd(s, vec![x]), &mut ctx).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].1.payload[0].shape(), &[2, 3]);
        assert_eq!(node.cached_keys(), 1);
        let before = node.params()[0].clone();
        let dy = Tensor::from_rows(2, 3, vec![1.0; 6]);
        let back = node.backward(0, Message::bwd(s, vec![dy]), &mut ctx).unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].1.payload[0].shape(), &[2, 4]);
        assert_eq!(node.cached_keys(), 0);
        assert_ne!(node.params()[0], before, "update applied (muf=1)");
        assert!(matches!(rx.try_recv().unwrap(), Event::Update { .. }));
    }

    #[test]
    fn bucketing_pads_and_slices() {
        let (mut be, tx, _rx) = ctx_pair();
        let mut node = linear_ppt(1000, vec![1, 4, 16]);
        let mut ctx = NodeCtx { backend: &mut be, events: &tx, node_id: 0 };
        let s = MsgState::for_instance(2);
        let x = Tensor::from_rows(3, 4, vec![0.1; 12]); // pads to bucket 4
        let out = node.forward(0, Message::fwd(s, vec![x]), &mut ctx).unwrap();
        assert_eq!(out[0].1.payload[0].shape(), &[3, 3]);
        let dy = Tensor::from_rows(3, 3, vec![1.0; 9]);
        let back = node.backward(0, Message::bwd(s, vec![dy]), &mut ctx).unwrap();
        assert_eq!(back[0].1.payload[0].shape(), &[3, 4]);
        // 3 rows accumulated toward muf
        assert_eq!(node.params.pending, 3);
    }

    #[test]
    fn eval_messages_leave_no_cache() {
        let (mut be, tx, _rx) = ctx_pair();
        let mut node = linear_ppt(1, vec![2]);
        let mut ctx = NodeCtx { backend: &mut be, events: &tx, node_id: 0 };
        let s = MsgState::for_instance(3);
        let x = Tensor::from_rows(2, 4, vec![0.5; 8]);
        node.forward(0, Message::eval(s, vec![x]), &mut ctx).unwrap();
        assert_eq!(node.cached_keys(), 0);
    }

    #[test]
    fn interleaved_instances_do_not_conflate() {
        // the point of state-keyed caching: two instances in flight
        let (mut be, tx, _rx) = ctx_pair();
        let mut node = linear_ppt(1000, vec![1]);
        let mut ctx = NodeCtx { backend: &mut be, events: &tx, node_id: 0 };
        let s1 = MsgState::for_instance(1);
        let s2 = MsgState::for_instance(2);
        let x1 = Tensor::from_rows(1, 4, vec![1.0; 4]);
        let x2 = Tensor::from_rows(1, 4, vec![2.0; 4]);
        node.forward(0, Message::fwd(s1, vec![x1.clone()]), &mut ctx).unwrap();
        node.forward(0, Message::fwd(s2, vec![x2]), &mut ctx).unwrap();
        assert_eq!(node.cached_keys(), 2);
        // backward for instance 1 must use instance 1's activation:
        // dW = x1^T dy
        let dy = Tensor::from_rows(1, 3, vec![1.0; 3]);
        node.backward(0, Message::bwd(s1, vec![dy]), &mut ctx).unwrap();
        // pending weight is 1 row; grads reflect x1 (all 1.0): dW entries = 1
        assert_eq!(node.params.pending, 1);
        assert_eq!(node.cached_keys(), 1);
    }

    #[test]
    fn version_tags_roundtrip_through_forward_and_backward() {
        let (mut be, tx, _rx) = ctx_pair();
        let mut node = linear_ppt(1000, vec![2]);
        let mut ctx = NodeCtx { backend: &mut be, events: &tx, node_id: 0 };
        let s = MsgState::for_instance(4);
        let x = Tensor::from_rows(2, 4, vec![0.5; 8]);
        // incoming forward tagged as if an upstream node produced it at
        // parameter version 9
        let out = node.forward(0, Message::fwd(s, vec![x]).versioned(9), &mut ctx).unwrap();
        assert_eq!(
            out[0].1.param_version,
            Some(0),
            "forward output carries THIS node's version"
        );
        let dy = Tensor::from_rows(2, 3, vec![1.0; 6]);
        let back = node
            .backward(0, Message::bwd(s, vec![dy]).versioned(0), &mut ctx)
            .unwrap();
        assert_eq!(
            back[0].1.param_version,
            Some(9),
            "cotangent echoes the upstream producer's tag"
        );
    }

    #[test]
    fn backward_without_forward_is_an_error() {
        let (mut be, tx, _rx) = ctx_pair();
        let mut node = linear_ppt(1, vec![2]);
        let mut ctx = NodeCtx { backend: &mut be, events: &tx, node_id: 0 };
        let s = MsgState::for_instance(9);
        let dy = Tensor::from_rows(2, 3, vec![1.0; 6]);
        assert!(node.backward(0, Message::bwd(s, vec![dy]), &mut ctx).is_err());
    }

    #[test]
    fn multi_port_join_waits_for_all_ports() {
        // gru: port0 = m, port1 = h
        let mut rng = Pcg32::seeded(3);
        let (i, h) = (4usize, 3usize);
        let params = vec![
            glorot(&mut rng, i, 3 * h),
            glorot(&mut rng, h, 3 * h),
            Tensor::zeros(&[3 * h]),
        ];
        let mut node = PptNode::new(
            "gru",
            PptConfig {
                op: "gru".into(),
                flavor: KernelFlavor::Xla,
                dims: vec![("i".into(), i), ("h".into(), h)],
                buckets: vec![2],
                in_port_arity: vec![1, 1],
                n_outputs: 1,
                join_key: None,
                out_state: None,
            },
            params,
            Optimizer::sgd(0.1),
            1,
        );
        let (mut be, tx, _rx) = ctx_pair();
        let mut ctx = NodeCtx { backend: &mut be, events: &tx, node_id: 0 };
        let s = MsgState::for_instance(1);
        let m = Tensor::from_rows(2, i, vec![0.3; 2 * i]);
        let hh = Tensor::from_rows(2, h, vec![0.1; 2 * h]);
        let r1 = node.forward(0, Message::fwd(s, vec![m]), &mut ctx).unwrap();
        assert!(r1.is_empty(), "waits for port 1");
        let r2 = node.forward(1, Message::fwd(s, vec![hh]), &mut ctx).unwrap();
        assert_eq!(r2.len(), 1);
        assert_eq!(r2[0].1.payload[0].shape(), &[2, h]);
        // backward routes dm to port 0 and dh to port 1
        let dhn = Tensor::from_rows(2, h, vec![1.0; 2 * h]);
        let back = node.backward(0, Message::bwd(s, vec![dhn]), &mut ctx).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].0, 0);
        assert_eq!(back[0].1.payload[0].shape(), &[2, i]);
        assert_eq!(back[1].0, 1);
        assert_eq!(back[1].1.payload[0].shape(), &[2, h]);
    }
}
