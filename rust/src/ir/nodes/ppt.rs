//! Parameterized payload transform (PPT) — the workhorse IR node.
//!
//! A PPT node wraps a (fwd, bwd) artifact pair plus a local [`ParamSet`].
//! Forward: join data inputs across its input ports (keyed by message
//! state), pad the batch to an allowed bucket, execute the fwd artifact,
//! park the (unpadded) inputs in the runtime stash keyed by state — "an
//! activation is recorded by keying on the state of the message" (§4) —
//! and emit the outputs.
//! Backward: replay the stashed inputs through the bwd artifact, route
//! the input cotangents back per port, and accumulate parameter
//! gradients, applying a local update whenever `min_update_frequency`
//! rows have been seen (§3).
//!
//! All cross-cutting concerns (version tagging, train/eval handling,
//! cache leak accounting) live in the node runtime ([`crate::ir::rt`]):
//! this file is pure compute plus stash choreography.

use anyhow::{anyhow, Result};

use crate::ir::graph::{Event, Node, PortId};
use crate::ir::rt::NodeCtx;
use crate::ir::state::{MsgState, StateKey};
use crate::optim::{Optimizer, ParamSet};
use crate::runtime::{artifact_name, KernelFlavor};
use crate::tensor::Tensor;
use crate::util::stats::bucket_for;

/// Configuration of a PPT node.
pub struct PptConfig {
    /// Artifact op stem, e.g. "linear_relu" (expands to `<op>_fwd`/`<op>_bwd`).
    pub op: String,
    /// Which lowering of the op to execute.
    pub flavor: KernelFlavor,
    /// Artifact dims *excluding* the batch dim `b`, e.g. [("i",784),("o",784)].
    pub dims: Vec<(String, usize)>,
    /// Allowed batch buckets (ascending). Payload rows are zero-padded up
    /// to the nearest bucket; single-bucket models use `vec![B]`.
    pub buckets: Vec<usize>,
    /// Payload tensors expected per input port (e.g. branch LSTM: [2, 2]).
    pub in_port_arity: Vec<usize>,
    /// How many outputs the fwd artifact produces (all flow out of port 0
    /// as one message).
    pub n_outputs: usize,
    /// Multi-port join key ("a Phi/PPT node must be parameterized over
    /// the keying function on the state", §4). Default: the full state.
    /// The tree-LSTM branch cell keys on (instance, node) so that left
    /// and right child messages — which differ in `edge` — meet.
    pub join_key: Option<Box<dyn Fn(&MsgState) -> StateKey + Send>>,
    /// State of the emitted output message (default: the state of the
    /// port-0 input). The branch cell canonicalizes `edge = 0` here.
    pub out_state: Option<Box<dyn Fn(&MsgState) -> MsgState + Send>>,
}

impl PptConfig {
    /// Common case: 1 input port, 1 payload tensor, 1 output.
    pub fn simple(
        op: &str,
        flavor: KernelFlavor,
        dims: &[(&str, usize)],
        buckets: Vec<usize>,
    ) -> Self {
        PptConfig {
            op: op.to_string(),
            flavor,
            dims: dims.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
            buckets,
            in_port_arity: vec![1],
            n_outputs: 1,
            join_key: None,
            out_state: None,
        }
    }
}

/// Join buffer: per-port (state, payload), filled as messages arrive.
struct PendingJoin {
    ports: Vec<Option<(MsgState, Vec<Tensor>)>>,
}

/// Activation record replayed by the backward pass. Producer tags and
/// the mode flag are threaded by the runtime stash, not stored here.
struct FwdCache {
    /// Data inputs in artifact order (unpadded).
    data_inputs: Vec<Tensor>,
    /// Original per-port input states (backward messages restore these).
    port_states: Vec<MsgState>,
}

pub struct PptNode {
    label: String,
    cfg: PptConfig,
    pub params: ParamSet,
}

impl PptNode {
    pub fn new(
        label: &str,
        cfg: PptConfig,
        params: Vec<Tensor>,
        opt: Optimizer,
        min_update_frequency: usize,
    ) -> Self {
        assert!(!cfg.buckets.is_empty(), "{label}: empty buckets");
        assert!(!cfg.in_port_arity.is_empty());
        PptNode {
            label: label.to_string(),
            cfg,
            params: ParamSet::new(params, opt, min_update_frequency),
        }
    }

    fn art(&self, which: &str, bucket: usize) -> String {
        let mut dims: Vec<(&str, usize)> =
            self.cfg.dims.iter().map(|(k, v)| (k.as_str(), *v)).collect();
        dims.push(("b", bucket));
        artifact_name(&format!("{}_{which}", self.cfg.op), &dims, self.cfg.flavor.as_str())
    }

    fn n_ports(&self) -> usize {
        self.cfg.in_port_arity.len()
    }

    /// Execute the forward artifact over joined inputs.
    fn run_forward(
        &mut self,
        port_states: Vec<MsgState>,
        data_inputs: Vec<Tensor>,
        ctx: &mut NodeCtx,
    ) -> Result<()> {
        let out_state = match &self.cfg.out_state {
            Some(f) => f(&port_states[0]),
            None => port_states[0],
        };
        let rows = data_inputs[0].rows();
        let bucket = bucket_for(rows, &self.cfg.buckets);
        let mut args: Vec<Tensor> =
            data_inputs.iter().map(|t| t.pad_rows(bucket)).collect();
        // Serving requests read the CoW snapshot so concurrent training
        // updates can't tear a response (DESIGN.md §15).
        let params =
            if ctx.serving() { self.params.serve_params() } else { self.params.params() };
        args.extend(params.iter().cloned());
        let name = self.art("fwd", bucket);
        let outs = ctx.backend.execute(&name, &args)?;
        let outs: Vec<Tensor> = outs
            .into_iter()
            .map(|t| if t.rows() > rows { t.slice_rows(0, rows) } else { t })
            .collect();
        ctx.stash_bwd(out_state.key(), FwdCache { data_inputs, port_states })?;
        ctx.emit_fwd(0, out_state, outs);
        Ok(())
    }
}

impl Node for PptNode {
    fn forward(
        &mut self,
        port: PortId,
        state: MsgState,
        payload: Vec<Tensor>,
        ctx: &mut NodeCtx,
    ) -> Result<()> {
        anyhow::ensure!(port < self.n_ports(), "{}: bad input port {port}", self.label);
        anyhow::ensure!(
            payload.len() == self.cfg.in_port_arity[port],
            "{}: port {port} expects {} tensors, got {}",
            self.label,
            self.cfg.in_port_arity[port],
            payload.len()
        );
        if self.n_ports() == 1 {
            return self.run_forward(vec![state], payload, ctx);
        }
        // Multi-port join, keyed by the configured keying function (§4).
        let key = match &self.cfg.join_key {
            Some(f) => f(&state),
            None => state.key(),
        };
        let n_ports = self.n_ports();
        let mut join = ctx
            .take::<PendingJoin>(key)
            .unwrap_or_else(|| PendingJoin { ports: (0..n_ports).map(|_| None).collect() });
        anyhow::ensure!(
            join.ports[port].is_none(),
            "{}: duplicate join on port {port}",
            self.label
        );
        join.ports[port] = Some((state, payload));
        if join.ports.iter().all(Option::is_some) {
            let mut data = Vec::new();
            let mut states = Vec::with_capacity(n_ports);
            for p in join.ports {
                let (s, payload) = p.unwrap();
                states.push(s);
                data.extend(payload);
            }
            self.run_forward(states, data, ctx)
        } else {
            ctx.stash(key, join)
        }
    }

    fn backward(
        &mut self,
        _port: PortId,
        state: MsgState,
        payload: Vec<Tensor>,
        ctx: &mut NodeCtx,
    ) -> Result<()> {
        anyhow::ensure!(
            payload.len() == self.cfg.n_outputs,
            "{}: backward expects {} cotangents, got {}",
            self.label,
            self.cfg.n_outputs,
            payload.len()
        );
        let cached: FwdCache = ctx
            .take(state.key())
            .ok_or_else(|| anyhow!("{}: no cached activation for {:?}", self.label, state))?;
        let rows = cached.data_inputs[0].rows();
        let bucket = bucket_for(rows, &self.cfg.buckets);
        let mut args: Vec<Tensor> =
            cached.data_inputs.iter().map(|t| t.pad_rows(bucket)).collect();
        args.extend(self.params.params().iter().cloned());
        args.extend(payload.iter().map(|t| t.pad_rows(bucket)));
        let name = self.art("bwd", bucket);
        let outs = ctx.backend.execute(&name, &args)?;
        let n_data: usize = self.cfg.in_port_arity.iter().sum();
        anyhow::ensure!(
            outs.len() == n_data + self.params.params().len(),
            "{}: bwd artifact arity mismatch ({} vs {})",
            self.label,
            outs.len(),
            n_data + self.params.params().len()
        );
        // Parameter gradients: accumulate locally; update when ready (§3).
        // Staleness is the version delta between the node's counter now
        // and the version its forward pass ran at — the runtime recovers
        // the latter from the backward echo (ledger fallback).
        let version_at_fwd = ctx.fwd_version().unwrap_or(self.params.updates);
        let staleness = self.params.updates.saturating_sub(version_at_fwd);
        self.params.accumulate_stale(&outs[n_data..], rows, staleness);
        if self.params.maybe_update() {
            ctx.emit(Event::update(ctx.node_id, self.params.take_staleness_stats()));
        }
        // Input cotangents: slice padding away, split per port, restoring
        // each port's original input state; the runtime echoes each
        // port's producer tag upstream.
        let mut idx = 0;
        for (port, &arity) in self.cfg.in_port_arity.iter().enumerate() {
            let tensors: Vec<Tensor> = outs[idx..idx + arity]
                .iter()
                .map(|t| if t.rows() > rows { t.slice_rows(0, rows) } else { t.clone() })
                .collect();
            idx += arity;
            ctx.emit_bwd(port, cached.port_states[port], tensors);
        }
        Ok(())
    }

    fn version(&self) -> Option<u64> {
        Some(self.params.updates)
    }

    fn params(&self) -> Vec<Tensor> {
        self.params.params().to_vec()
    }

    fn set_params(&mut self, params: Vec<Tensor>) {
        self.params.set_params(params);
    }

    fn snapshot_params(&mut self) {
        self.params.capture_snapshot();
    }

    fn flush(&mut self, ctx: &mut NodeCtx) -> Result<()> {
        if self.params.pending > 0 && self.params.update() {
            ctx.emit(Event::update(ctx.node_id, self.params.take_staleness_stats()));
        }
        Ok(())
    }

    fn opt_state(&self) -> Option<crate::optim::OptState> {
        Some(self.params.opt_state())
    }

    fn set_opt_state(&mut self, state: crate::optim::OptState) -> Result<()> {
        self.params.set_opt_state(state)
    }

    fn name(&self) -> &str {
        &self.label
    }
}

/// Glorot-uniform initialization for a [fan_in, fan_out] weight matrix.
pub fn glorot(rng: &mut crate::util::Pcg32, fan_in: usize, fan_out: usize) -> Tensor {
    let limit = (6.0 / (fan_in + fan_out) as f32).sqrt();
    Tensor::new(
        vec![fan_in, fan_out],
        (0..fan_in * fan_out).map(|_| rng.range(-limit, limit)).collect(),
    )
}

/// Linear-layer parameter pair (glorot W, zero b).
pub fn linear_params(rng: &mut crate::util::Pcg32, i: usize, o: usize) -> Vec<Tensor> {
    vec![glorot(rng, i, o), Tensor::zeros(&[o])]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::graph::Event;
    use crate::ir::message::Message;
    use crate::ir::rt::{invoke_msg, NodeRt};
    use crate::runtime::NativeBackend;
    use crate::util::Pcg32;
    use std::sync::mpsc::channel;

    struct Rig {
        be: NativeBackend,
        tx: std::sync::mpsc::Sender<Event>,
        rx: std::sync::mpsc::Receiver<Event>,
        rt: NodeRt,
    }

    impl Rig {
        fn new() -> Self {
            let (tx, rx) = channel();
            Rig { be: NativeBackend::new(), tx, rx, rt: NodeRt::new() }
        }

        fn drive(
            &mut self,
            node: &mut dyn Node,
            port: PortId,
            msg: Message,
        ) -> Result<Vec<(PortId, Message)>> {
            invoke_msg(node, &mut self.rt, &mut self.be, &self.tx, 0, port, msg)
        }
    }

    fn linear_ppt(muf: usize, buckets: Vec<usize>) -> PptNode {
        let mut rng = Pcg32::seeded(7);
        PptNode::new(
            "lin",
            PptConfig::simple("linear", KernelFlavor::Xla, &[("i", 4), ("o", 3)], buckets),
            linear_params(&mut rng, 4, 3),
            Optimizer::sgd(0.1),
            muf,
        )
    }

    #[test]
    fn forward_then_backward_roundtrip_updates_params() {
        let mut rig = Rig::new();
        let mut node = linear_ppt(1, vec![2]);
        let s = MsgState::for_instance(1);
        let x = Tensor::from_rows(2, 4, vec![0.5; 8]);
        let out = rig.drive(&mut node, 0, Message::fwd(s, vec![x])).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].1.payload[0].shape(), &[2, 3]);
        // one activation stash + one runtime echo-ledger entry
        assert_eq!(rig.rt.cached(), 2);
        let before = node.params()[0].clone();
        let dy = Tensor::from_rows(2, 3, vec![1.0; 6]);
        let back = rig.drive(&mut node, 0, Message::bwd(s, vec![dy])).unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].1.payload[0].shape(), &[2, 4]);
        assert_eq!(rig.rt.cached(), 0);
        assert_ne!(node.params()[0], before, "update applied (muf=1)");
        assert!(matches!(rig.rx.try_recv().unwrap(), Event::Update { .. }));
    }

    #[test]
    fn bucketing_pads_and_slices() {
        let mut rig = Rig::new();
        let mut node = linear_ppt(1000, vec![1, 4, 16]);
        let s = MsgState::for_instance(2);
        let x = Tensor::from_rows(3, 4, vec![0.1; 12]); // pads to bucket 4
        let out = rig.drive(&mut node, 0, Message::fwd(s, vec![x])).unwrap();
        assert_eq!(out[0].1.payload[0].shape(), &[3, 3]);
        let dy = Tensor::from_rows(3, 3, vec![1.0; 9]);
        let back = rig.drive(&mut node, 0, Message::bwd(s, vec![dy])).unwrap();
        assert_eq!(back[0].1.payload[0].shape(), &[3, 4]);
        // 3 rows accumulated toward muf
        assert_eq!(node.params.pending, 3);
    }

    #[test]
    fn eval_messages_leave_no_cache() {
        let mut rig = Rig::new();
        let mut node = linear_ppt(1, vec![2]);
        let s = MsgState::for_instance(3);
        let x = Tensor::from_rows(2, 4, vec![0.5; 8]);
        rig.drive(&mut node, 0, Message::eval(s, vec![x])).unwrap();
        assert_eq!(rig.rt.cached(), 0);
    }

    #[test]
    fn interleaved_instances_do_not_conflate() {
        // the point of state-keyed caching: two instances in flight
        let mut rig = Rig::new();
        let mut node = linear_ppt(1000, vec![1]);
        let s1 = MsgState::for_instance(1);
        let s2 = MsgState::for_instance(2);
        let x1 = Tensor::from_rows(1, 4, vec![1.0; 4]);
        let x2 = Tensor::from_rows(1, 4, vec![2.0; 4]);
        rig.drive(&mut node, 0, Message::fwd(s1, vec![x1.clone()])).unwrap();
        rig.drive(&mut node, 0, Message::fwd(s2, vec![x2])).unwrap();
        assert_eq!(rig.rt.cached(), 4, "two stashes + two ledger entries");
        // backward for instance 1 must use instance 1's activation:
        // dW = x1^T dy
        let dy = Tensor::from_rows(1, 3, vec![1.0; 3]);
        rig.drive(&mut node, 0, Message::bwd(s1, vec![dy])).unwrap();
        // pending weight is 1 row; grads reflect x1 (all 1.0): dW entries = 1
        assert_eq!(node.params.pending, 1);
        assert_eq!(rig.rt.cached(), 2);
    }

    #[test]
    fn version_tags_roundtrip_through_forward_and_backward() {
        let mut rig = Rig::new();
        let mut node = linear_ppt(1000, vec![2]);
        let s = MsgState::for_instance(4);
        let x = Tensor::from_rows(2, 4, vec![0.5; 8]);
        // incoming forward tagged as if an upstream node produced it at
        // parameter version 9
        let out = rig.drive(&mut node, 0, Message::fwd(s, vec![x]).versioned(9)).unwrap();
        assert_eq!(
            out[0].1.version(),
            Some(0),
            "forward output carries THIS node's version"
        );
        let dy = Tensor::from_rows(2, 3, vec![1.0; 6]);
        let back = rig.drive(&mut node, 0, Message::bwd(s, vec![dy]).versioned(0)).unwrap();
        assert_eq!(
            back[0].1.version(),
            Some(9),
            "cotangent echoes the upstream producer's tag"
        );
    }

    #[test]
    fn backward_without_forward_is_an_error() {
        let mut rig = Rig::new();
        let mut node = linear_ppt(1, vec![2]);
        let s = MsgState::for_instance(9);
        let dy = Tensor::from_rows(2, 3, vec![1.0; 6]);
        assert!(rig.drive(&mut node, 0, Message::bwd(s, vec![dy])).is_err());
    }

    #[test]
    fn multi_port_join_waits_for_all_ports() {
        // gru: port0 = m, port1 = h
        let mut rng = Pcg32::seeded(3);
        let (i, h) = (4usize, 3usize);
        let params = vec![
            glorot(&mut rng, i, 3 * h),
            glorot(&mut rng, h, 3 * h),
            Tensor::zeros(&[3 * h]),
        ];
        let mut node = PptNode::new(
            "gru",
            PptConfig {
                op: "gru".into(),
                flavor: KernelFlavor::Xla,
                dims: vec![("i".into(), i), ("h".into(), h)],
                buckets: vec![2],
                in_port_arity: vec![1, 1],
                n_outputs: 1,
                join_key: None,
                out_state: None,
            },
            params,
            Optimizer::sgd(0.1),
            1,
        );
        let mut rig = Rig::new();
        let s = MsgState::for_instance(1);
        let m = Tensor::from_rows(2, i, vec![0.3; 2 * i]);
        let hh = Tensor::from_rows(2, h, vec![0.1; 2 * h]);
        let r1 = rig.drive(&mut node, 0, Message::fwd(s, vec![m])).unwrap();
        assert!(r1.is_empty(), "waits for port 1");
        let r2 = rig.drive(&mut node, 1, Message::fwd(s, vec![hh])).unwrap();
        assert_eq!(r2.len(), 1);
        assert_eq!(r2[0].1.payload[0].shape(), &[2, h]);
        // backward routes dm to port 0 and dh to port 1
        let dhn = Tensor::from_rows(2, h, vec![1.0; 2 * h]);
        let back = rig.drive(&mut node, 0, Message::bwd(s, vec![dhn])).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].0, 0);
        assert_eq!(back[0].1.payload[0].shape(), &[2, i]);
        assert_eq!(back[1].0, 1);
        assert_eq!(back[1].1.payload[0].shape(), &[2, h]);
    }

    #[test]
    fn multi_port_join_echoes_per_port_tags() {
        // two producers at different versions feeding one join: the
        // forward output carries the max; each backward cotangent echoes
        // its own port's producer tag.
        let mut rng = Pcg32::seeded(5);
        let (i, h) = (4usize, 3usize);
        let params = vec![
            glorot(&mut rng, i, 3 * h),
            glorot(&mut rng, h, 3 * h),
            Tensor::zeros(&[3 * h]),
        ];
        let mut node = PptNode::new(
            "gru",
            PptConfig {
                op: "gru".into(),
                flavor: KernelFlavor::Xla,
                dims: vec![("i".into(), i), ("h".into(), h)],
                buckets: vec![1],
                in_port_arity: vec![1, 1],
                n_outputs: 1,
                join_key: None,
                out_state: None,
            },
            params,
            Optimizer::sgd(0.1),
            1_000_000,
        );
        let mut rig = Rig::new();
        let s = MsgState::for_instance(8);
        let m = Tensor::from_rows(1, i, vec![0.3; i]);
        let hh = Tensor::from_rows(1, h, vec![0.1; h]);
        rig.drive(&mut node, 0, Message::fwd(s, vec![m]).versioned(5)).unwrap();
        let out = rig.drive(&mut node, 1, Message::fwd(s, vec![hh]).versioned(2)).unwrap();
        assert_eq!(out[0].1.version(), Some(0), "parameterized join stamps its own version");
        let dhn = Tensor::from_rows(1, h, vec![1.0; h]);
        let back = rig.drive(&mut node, 0, Message::bwd(s, vec![dhn]).versioned(0)).unwrap();
        assert_eq!(back[0].1.version(), Some(5), "port 0 echoes its producer");
        assert_eq!(back[1].1.version(), Some(2), "port 1 echoes its producer");
    }
}
