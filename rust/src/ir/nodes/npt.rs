//! Non-parameterized payload transforms (§4): cheap native transforms
//! that need no artifact — payload selection, row reductions, transposes,
//! masking, dead-ends. Each has an exact backward. Shape records for the
//! backward pass live in the runtime stash (train-only, leak-accounted).

use anyhow::{anyhow, Result};

use crate::ir::graph::{Node, PortId};
use crate::ir::rt::NodeCtx;
use crate::ir::state::MsgState;
use crate::tensor::{ops, Tensor};

/// The transform kinds.
pub enum NptKind {
    /// Pass through payload tensors at `indices` only. Backward restores
    /// full arity with zeros in unselected positions.
    Select { indices: Vec<usize> },
    /// Sum rows: [N, D] -> [1, D]. Backward replicates the cotangent row
    /// N times (N cached at forward).
    SumRows,
    /// Transpose the single payload tensor. Backward transposes back.
    Transpose,
    /// Scale payload by a constant (e.g. 1/N normalization).
    Scale { factor: f32 },
    /// Set columns >= state.aux to `neg` (mask padded graph nodes before a
    /// softmax-over-nodes). Backward zeros those columns.
    MaskColsBeyondAux { neg: f32 },
    /// Pad columns up to `to` with `fill` (match a fixed-width loss
    /// artifact; fill = -1e9 makes padded logits inert under softmax).
    /// Backward slices the cotangent back.
    PadCols { to: usize, fill: f32 },
    /// Accept a forward message and immediately reflect a zero cotangent
    /// (a path that exists for control-flow reasons but carries no loss,
    /// e.g. the tree root's unused parent edge).
    DeadEnd,
}

/// Forward-side shape record for kinds whose backward needs it.
struct Shapes(Vec<Vec<usize>>);

pub struct NptNode {
    label: String,
    kind: NptKind,
}

impl NptNode {
    pub fn new(label: &str, kind: NptKind) -> Self {
        NptNode { label: label.to_string(), kind }
    }

    fn one<'p>(&self, payload: &'p [Tensor]) -> Result<&'p Tensor> {
        super::single(&self.label, payload)
    }
}

impl Node for NptNode {
    fn forward(
        &mut self,
        _port: PortId,
        state: MsgState,
        payload: Vec<Tensor>,
        ctx: &mut NodeCtx,
    ) -> Result<()> {
        match &self.kind {
            NptKind::Select { indices } => {
                let shapes = payload.iter().map(|t| t.shape().to_vec()).collect();
                ctx.stash_bwd(state.key(), Shapes(shapes))?;
                let picked: Vec<Tensor> = indices
                    .iter()
                    .map(|&i| {
                        payload
                            .get(i)
                            .cloned()
                            .ok_or_else(|| anyhow!("{}: select index {i} out of range", self.label))
                    })
                    .collect::<Result<_>>()?;
                ctx.emit_fwd(0, state, picked);
            }
            NptKind::SumRows => {
                let t = self.one(&payload)?;
                ctx.stash_bwd(state.key(), Shapes(vec![t.shape().to_vec()]))?;
                let sum = ops::col_sum(t).reshape(vec![1, t.cols()]);
                ctx.emit_fwd(0, state, vec![sum]);
            }
            NptKind::Transpose => {
                let out = ops::transpose(self.one(&payload)?);
                ctx.emit_fwd(0, state, vec![out]);
            }
            NptKind::Scale { factor } => {
                let mut t = self.one(&payload)?.clone();
                t.scale(*factor);
                ctx.emit_fwd(0, state, vec![t]);
            }
            NptKind::MaskColsBeyondAux { neg } => {
                let mut t = self.one(&payload)?.clone();
                let n = state.aux as usize;
                for r in 0..t.rows() {
                    for c in n..t.cols() {
                        *t.at_mut(r, c) = *neg;
                    }
                }
                ctx.emit_fwd(0, state, vec![t]);
            }
            NptKind::PadCols { to, fill } => {
                let t = self.one(&payload)?;
                anyhow::ensure!(
                    t.cols() <= *to,
                    "{}: {} cols > pad target {to}",
                    self.label,
                    t.cols()
                );
                ctx.stash_bwd(state.key(), Shapes(vec![t.shape().to_vec()]))?;
                let mut out = Tensor::full(&[t.rows(), *to], *fill);
                for r in 0..t.rows() {
                    out.row_mut(r)[..t.cols()].copy_from_slice(t.row(r));
                }
                ctx.emit_fwd(0, state, vec![out]);
            }
            NptKind::DeadEnd => {
                if ctx.grad_enabled() {
                    let zeros = payload.iter().map(|t| Tensor::zeros(t.shape())).collect();
                    ctx.emit_bwd(0, state, zeros);
                }
            }
        }
        Ok(())
    }

    fn backward(
        &mut self,
        _port: PortId,
        state: MsgState,
        payload: Vec<Tensor>,
        ctx: &mut NodeCtx,
    ) -> Result<()> {
        let take_shapes = |ctx: &mut NodeCtx| -> Result<Vec<Vec<usize>>> {
            ctx.take::<Shapes>(state.key())
                .map(|s| s.0)
                .ok_or_else(|| anyhow!("{}: no shape record for {:?}", self.label, state))
        };
        match &self.kind {
            NptKind::Select { indices } => {
                let shapes = take_shapes(ctx)?;
                let mut full: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();
                anyhow::ensure!(payload.len() == indices.len(), "{}: arity", self.label);
                for (&i, t) in indices.iter().zip(&payload) {
                    full[i] = t.clone();
                }
                ctx.emit_bwd(0, state, full);
            }
            NptKind::SumRows => {
                let shapes = take_shapes(ctx)?;
                let n = shapes[0][0];
                let d = self.one(&payload)?;
                anyhow::ensure!(d.rows() == 1, "{}: cotangent must be [1, D]", self.label);
                let mut out = Tensor::zeros(&shapes[0]);
                for r in 0..n {
                    out.row_mut(r).copy_from_slice(d.row(0));
                }
                ctx.emit_bwd(0, state, vec![out]);
            }
            NptKind::Transpose => {
                let out = ops::transpose(self.one(&payload)?);
                ctx.emit_bwd(0, state, vec![out]);
            }
            NptKind::Scale { factor } => {
                let mut t = self.one(&payload)?.clone();
                t.scale(*factor);
                ctx.emit_bwd(0, state, vec![t]);
            }
            NptKind::MaskColsBeyondAux { .. } => {
                let mut t = self.one(&payload)?.clone();
                let n = state.aux as usize;
                for r in 0..t.rows() {
                    for c in n..t.cols() {
                        *t.at_mut(r, c) = 0.0;
                    }
                }
                ctx.emit_bwd(0, state, vec![t]);
            }
            NptKind::PadCols { .. } => {
                let shapes = take_shapes(ctx)?;
                let (rows, cols) = (shapes[0][0], shapes[0][1]);
                let d = self.one(&payload)?;
                let mut out = Tensor::zeros(&[rows, cols]);
                for r in 0..rows {
                    out.row_mut(r).copy_from_slice(&d.row(r)[..cols]);
                }
                ctx.emit_bwd(0, state, vec![out]);
            }
            NptKind::DeadEnd => {
                return Err(anyhow!("{}: DeadEnd never receives backward", self.label))
            }
        }
        Ok(())
    }

    fn name(&self) -> &str {
        &self.label
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::message::{Dir, Message};
    use crate::ir::rt::{invoke_msg, NodeRt};
    use crate::runtime::NativeBackend;
    use std::sync::mpsc::channel;

    fn drive(
        node: &mut NptNode,
        rt: &mut NodeRt,
        msg: Message,
    ) -> Vec<(PortId, Message)> {
        let (tx, _rx) = channel();
        let mut be = NativeBackend::new();
        invoke_msg(node, rt, &mut be, &tx, 0, 0, msg).unwrap()
    }

    fn run(kind: NptKind, msg: Message) -> (NptNode, NodeRt, Vec<(PortId, Message)>) {
        let mut n = NptNode::new("npt", kind);
        let mut rt = NodeRt::new();
        let out = drive(&mut n, &mut rt, msg);
        (n, rt, out)
    }

    #[test]
    fn select_picks_and_backfills_zeros() {
        let s = MsgState::for_instance(1);
        let h = Tensor::from_rows(1, 2, vec![1., 2.]);
        let c0 = Tensor::from_rows(1, 2, vec![3., 4.]);
        let (mut n, mut rt, out) =
            run(NptKind::Select { indices: vec![0] }, Message::fwd(s, vec![h, c0]));
        assert_eq!(out[0].1.payload.len(), 1);
        assert_eq!(out[0].1.tensor().data(), &[1., 2.]);
        let back = drive(
            &mut n,
            &mut rt,
            Message::bwd(s, vec![Tensor::from_rows(1, 2, vec![9., 9.])]),
        );
        assert_eq!(back[0].1.payload.len(), 2);
        assert_eq!(back[0].1.payload[0].data(), &[9., 9.]);
        assert_eq!(back[0].1.payload[1].data(), &[0., 0.]);
        assert_eq!(rt.cached(), 0);
    }

    #[test]
    fn sumrows_backward_replicates() {
        let s = MsgState::for_instance(2);
        let x = Tensor::from_rows(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let (mut n, mut rt, out) = run(NptKind::SumRows, Message::fwd(s, vec![x]));
        assert_eq!(out[0].1.tensor().data(), &[9., 12.]);
        let back = drive(
            &mut n,
            &mut rt,
            Message::bwd(s, vec![Tensor::from_rows(1, 2, vec![1., 10.])]),
        );
        assert_eq!(back[0].1.tensor().shape(), &[3, 2]);
        assert_eq!(back[0].1.tensor().row(2), &[1., 10.]);
    }

    #[test]
    fn mask_cols_beyond_aux() {
        let mut s = MsgState::for_instance(3);
        s.aux = 2;
        let x = Tensor::from_rows(1, 4, vec![5., 5., 5., 5.]);
        let (mut n, mut rt, out) =
            run(NptKind::MaskColsBeyondAux { neg: -1e9 }, Message::fwd(s, vec![x]));
        assert_eq!(out[0].1.tensor().data(), &[5., 5., -1e9, -1e9]);
        let back = drive(
            &mut n,
            &mut rt,
            Message::bwd(s, vec![Tensor::from_rows(1, 4, vec![1., 1., 1., 1.])]),
        );
        assert_eq!(back[0].1.tensor().data(), &[1., 1., 0., 0.]);
    }

    #[test]
    fn deadend_reflects_zero_bwd() {
        let s = MsgState::for_instance(4);
        let x = Tensor::from_rows(1, 2, vec![1., 2.]);
        let (_n, rt, out) = run(NptKind::DeadEnd, Message::fwd(s, vec![x]));
        assert_eq!(out[0].1.dir, Dir::Bwd);
        assert_eq!(out[0].1.tensor().data(), &[0., 0.]);
        assert_eq!(rt.cached(), 0, "reflection records nothing");
        // eval mode: silent sink
        let x = Tensor::from_rows(1, 2, vec![1., 2.]);
        let (_n, _rt, out) = run(NptKind::DeadEnd, Message::eval(s, vec![x]));
        assert!(out.is_empty());
    }

    #[test]
    fn transpose_roundtrip() {
        let s = MsgState::for_instance(5);
        let x = Tensor::from_rows(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let (mut n, mut rt, out) = run(NptKind::Transpose, Message::fwd(s, vec![x.clone()]));
        assert_eq!(out[0].1.tensor().shape(), &[3, 2]);
        let back = drive(&mut n, &mut rt, Message::bwd(s, vec![out[0].1.tensor().clone()]));
        assert_eq!(back[0].1.tensor(), &x);
    }

    #[test]
    fn version_tag_flows_through_and_echoes() {
        let s = MsgState::for_instance(6);
        let x = Tensor::from_rows(1, 2, vec![1., 2.]);
        let (mut n, mut rt, out) =
            run(NptKind::Scale { factor: 2.0 }, Message::fwd(s, vec![x]).versioned(4));
        assert_eq!(out[0].1.version(), Some(4), "glue propagates the tag");
        let back = drive(
            &mut n,
            &mut rt,
            Message::bwd(s, vec![Tensor::from_rows(1, 2, vec![1., 1.])]).versioned(4),
        );
        assert_eq!(back[0].1.version(), Some(4), "echo continues upstream");
        assert_eq!(rt.cached(), 0);
    }
}
