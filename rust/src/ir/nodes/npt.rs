//! Non-parameterized payload transforms (§4): cheap native transforms
//! that need no artifact — payload selection, row reductions, transposes,
//! masking, dead-ends. Each has an exact backward.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::ir::graph::{Node, NodeCtx, PortId};
use crate::ir::message::Message;
use crate::ir::state::StateKey;
use crate::tensor::{ops, Tensor};

/// The transform kinds.
pub enum NptKind {
    /// Pass through payload tensors at `indices` only. Backward restores
    /// full arity with zeros in unselected positions.
    Select { indices: Vec<usize> },
    /// Sum rows: [N, D] -> [1, D]. Backward replicates the cotangent row
    /// N times (N cached at forward).
    SumRows,
    /// Transpose the single payload tensor. Backward transposes back.
    Transpose,
    /// Scale payload by a constant (e.g. 1/N normalization).
    Scale { factor: f32 },
    /// Set columns >= state.aux to `neg` (mask padded graph nodes before a
    /// softmax-over-nodes). Backward zeros those columns.
    MaskColsBeyondAux { neg: f32 },
    /// Pad columns up to `to` with `fill` (match a fixed-width loss
    /// artifact; fill = -1e9 makes padded logits inert under softmax).
    /// Backward slices the cotangent back.
    PadCols { to: usize, fill: f32 },
    /// Accept a forward message and immediately reflect a zero cotangent
    /// (a path that exists for control-flow reasons but carries no loss,
    /// e.g. the tree root's unused parent edge).
    DeadEnd,
}

pub struct NptNode {
    label: String,
    kind: NptKind,
    /// Forward-side cache where the backward needs shape info.
    shapes: HashMap<StateKey, Vec<Vec<usize>>>,
}

impl NptNode {
    pub fn new(label: &str, kind: NptKind) -> Self {
        NptNode { label: label.to_string(), kind, shapes: HashMap::new() }
    }
}

impl Node for NptNode {
    fn forward(&mut self, _port: PortId, msg: Message, _ctx: &mut NodeCtx) -> Result<Vec<(PortId, Message)>> {
        let train = msg.train;
        let remember = |key: StateKey, shapes: Vec<Vec<usize>>, me: &mut HashMap<StateKey, Vec<Vec<usize>>>| {
            if train {
                me.insert(key, shapes);
            }
        };
        match &self.kind {
            NptKind::Select { indices } => {
                let shapes = msg.payload.iter().map(|t| t.shape().to_vec()).collect();
                remember(msg.state.key(), shapes, &mut self.shapes);
                let picked: Vec<Tensor> = indices
                    .iter()
                    .map(|&i| {
                        msg.payload
                            .get(i)
                            .cloned()
                            .ok_or_else(|| anyhow!("{}: select index {i} out of range", self.label))
                    })
                    .collect::<Result<_>>()?;
                let mut m = Message::fwd(msg.state, picked);
                m.train = train;
                Ok(vec![(0, m)])
            }
            NptKind::SumRows => {
                let t = msg.tensor();
                remember(msg.state.key(), vec![t.shape().to_vec()], &mut self.shapes);
                let sum = ops::col_sum(t).reshape(vec![1, t.cols()]);
                let mut m = Message::fwd(msg.state, vec![sum]);
                m.train = train;
                Ok(vec![(0, m)])
            }
            NptKind::Transpose => {
                let mut m = Message::fwd(msg.state, vec![ops::transpose(msg.tensor())]);
                m.train = train;
                Ok(vec![(0, m)])
            }
            NptKind::Scale { factor } => {
                let mut t = msg.tensor().clone();
                t.scale(*factor);
                let mut m = Message::fwd(msg.state, vec![t]);
                m.train = train;
                Ok(vec![(0, m)])
            }
            NptKind::MaskColsBeyondAux { neg } => {
                let mut t = msg.tensor().clone();
                let n = msg.state.aux as usize;
                for r in 0..t.rows() {
                    for c in n..t.cols() {
                        *t.at_mut(r, c) = *neg;
                    }
                }
                let mut m = Message::fwd(msg.state, vec![t]);
                m.train = train;
                Ok(vec![(0, m)])
            }
            NptKind::PadCols { to, fill } => {
                let t = msg.tensor();
                anyhow::ensure!(t.cols() <= *to, "{}: {} cols > pad target {to}", self.label, t.cols());
                remember(msg.state.key(), vec![t.shape().to_vec()], &mut self.shapes);
                let mut out = Tensor::full(&[t.rows(), *to], *fill);
                for r in 0..t.rows() {
                    out.row_mut(r)[..t.cols()].copy_from_slice(t.row(r));
                }
                let mut m = Message::fwd(msg.state, vec![out]);
                m.train = train;
                Ok(vec![(0, m)])
            }
            NptKind::DeadEnd => {
                if train {
                    let zeros = msg.payload.iter().map(|t| Tensor::zeros(t.shape())).collect();
                    Ok(vec![(0, Message::bwd(msg.state, zeros))])
                } else {
                    Ok(Vec::new())
                }
            }
        }
    }

    fn backward(&mut self, _port: PortId, msg: Message, _ctx: &mut NodeCtx) -> Result<Vec<(PortId, Message)>> {
        match &self.kind {
            NptKind::Select { indices } => {
                let shapes = self
                    .shapes
                    .remove(&msg.state.key())
                    .ok_or_else(|| anyhow!("{}: no shape record for {:?}", self.label, msg.state))?;
                let mut full: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();
                anyhow::ensure!(msg.payload.len() == indices.len(), "{}: arity", self.label);
                for (&i, t) in indices.iter().zip(&msg.payload) {
                    full[i] = t.clone();
                }
                Ok(vec![(0, Message::bwd(msg.state, full))])
            }
            NptKind::SumRows => {
                let shapes = self
                    .shapes
                    .remove(&msg.state.key())
                    .ok_or_else(|| anyhow!("{}: no shape record for {:?}", self.label, msg.state))?;
                let n = shapes[0][0];
                let d = msg.tensor();
                anyhow::ensure!(d.rows() == 1, "{}: cotangent must be [1, D]", self.label);
                let mut out = Tensor::zeros(&shapes[0]);
                for r in 0..n {
                    out.row_mut(r).copy_from_slice(d.row(0));
                }
                Ok(vec![(0, Message::bwd(msg.state, vec![out]))])
            }
            NptKind::Transpose => {
                Ok(vec![(0, Message::bwd(msg.state, vec![ops::transpose(msg.tensor())]))])
            }
            NptKind::Scale { factor } => {
                let mut t = msg.tensor().clone();
                t.scale(*factor);
                Ok(vec![(0, Message::bwd(msg.state, vec![t]))])
            }
            NptKind::MaskColsBeyondAux { .. } => {
                let mut t = msg.tensor().clone();
                let n = msg.state.aux as usize;
                for r in 0..t.rows() {
                    for c in n..t.cols() {
                        *t.at_mut(r, c) = 0.0;
                    }
                }
                Ok(vec![(0, Message::bwd(msg.state, vec![t]))])
            }
            NptKind::PadCols { .. } => {
                let shapes = self
                    .shapes
                    .remove(&msg.state.key())
                    .ok_or_else(|| anyhow!("{}: no shape record for {:?}", self.label, msg.state))?;
                let (rows, cols) = (shapes[0][0], shapes[0][1]);
                let d = msg.tensor();
                let mut out = Tensor::zeros(&[rows, cols]);
                for r in 0..rows {
                    out.row_mut(r).copy_from_slice(&d.row(r)[..cols]);
                }
                Ok(vec![(0, Message::bwd(msg.state, vec![out]))])
            }
            NptKind::DeadEnd => Err(anyhow!("{}: DeadEnd never receives backward", self.label)),
        }
    }

    fn cached_keys(&self) -> usize {
        self.shapes.len()
    }

    fn name(&self) -> &str {
        &self.label
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::graph::Event;
    use crate::ir::message::Dir;
    use crate::ir::state::MsgState;
    use crate::runtime::NativeBackend;
    use std::sync::mpsc::channel;

    fn run(kind: NptKind, msg: Message) -> (NptNode, Vec<(PortId, Message)>) {
        let mut n = NptNode::new("npt", kind);
        let (tx, _rx) = channel();
        let mut be = NativeBackend::new();
        let mut c = NodeCtx { backend: &mut be, events: &tx, node_id: 0 };
        let out = n.forward(0, msg, &mut c).unwrap();
        (n, out)
    }

    #[test]
    fn select_picks_and_backfills_zeros() {
        let s = MsgState::for_instance(1);
        let h = Tensor::from_rows(1, 2, vec![1., 2.]);
        let c0 = Tensor::from_rows(1, 2, vec![3., 4.]);
        let (mut n, out) = run(NptKind::Select { indices: vec![0] }, Message::fwd(s, vec![h, c0]));
        assert_eq!(out[0].1.payload.len(), 1);
        assert_eq!(out[0].1.tensor().data(), &[1., 2.]);
        let (tx, _rx) = channel();
        let mut be = NativeBackend::new();
        let mut c = NodeCtx { backend: &mut be, events: &tx, node_id: 0 };
        let back = n
            .backward(0, Message::bwd(s, vec![Tensor::from_rows(1, 2, vec![9., 9.])]), &mut c)
            .unwrap();
        assert_eq!(back[0].1.payload.len(), 2);
        assert_eq!(back[0].1.payload[0].data(), &[9., 9.]);
        assert_eq!(back[0].1.payload[1].data(), &[0., 0.]);
    }

    #[test]
    fn sumrows_backward_replicates() {
        let s = MsgState::for_instance(2);
        let x = Tensor::from_rows(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let (mut n, out) = run(NptKind::SumRows, Message::fwd(s, vec![x]));
        assert_eq!(out[0].1.tensor().data(), &[9., 12.]);
        let (tx, _rx) = channel();
        let mut be = NativeBackend::new();
        let mut c = NodeCtx { backend: &mut be, events: &tx, node_id: 0 };
        let back = n
            .backward(0, Message::bwd(s, vec![Tensor::from_rows(1, 2, vec![1., 10.])]), &mut c)
            .unwrap();
        assert_eq!(back[0].1.tensor().shape(), &[3, 2]);
        assert_eq!(back[0].1.tensor().row(2), &[1., 10.]);
    }

    #[test]
    fn mask_cols_beyond_aux() {
        let mut s = MsgState::for_instance(3);
        s.aux = 2;
        let x = Tensor::from_rows(1, 4, vec![5., 5., 5., 5.]);
        let (mut n, out) = run(NptKind::MaskColsBeyondAux { neg: -1e9 }, Message::fwd(s, vec![x]));
        assert_eq!(out[0].1.tensor().data(), &[5., 5., -1e9, -1e9]);
        let (tx, _rx) = channel();
        let mut be = NativeBackend::new();
        let mut c = NodeCtx { backend: &mut be, events: &tx, node_id: 0 };
        let back = n
            .backward(0, Message::bwd(s, vec![Tensor::from_rows(1, 4, vec![1., 1., 1., 1.])]), &mut c)
            .unwrap();
        assert_eq!(back[0].1.tensor().data(), &[1., 1., 0., 0.]);
    }

    #[test]
    fn deadend_reflects_zero_bwd() {
        let s = MsgState::for_instance(4);
        let x = Tensor::from_rows(1, 2, vec![1., 2.]);
        let (_n, out) = run(NptKind::DeadEnd, Message::fwd(s, vec![x]));
        assert_eq!(out[0].1.dir, Dir::Bwd);
        assert_eq!(out[0].1.tensor().data(), &[0., 0.]);
        // eval mode: silent sink
        let x = Tensor::from_rows(1, 2, vec![1., 2.]);
        let (_n, out) = run(NptKind::DeadEnd, Message::eval(s, vec![x]));
        assert!(out.is_empty());
    }

    #[test]
    fn transpose_roundtrip() {
        let s = MsgState::for_instance(5);
        let x = Tensor::from_rows(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let (mut n, out) = run(NptKind::Transpose, Message::fwd(s, vec![x.clone()]));
        assert_eq!(out[0].1.tensor().shape(), &[3, 2]);
        let (tx, _rx) = channel();
        let mut be = NativeBackend::new();
        let mut c = NodeCtx { backend: &mut be, events: &tx, node_id: 0 };
        let back = n.backward(0, Message::bwd(s, vec![out[0].1.tensor().clone()]), &mut c).unwrap();
        assert_eq!(back[0].1.tensor(), &x);
    }
}
