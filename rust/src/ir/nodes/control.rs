//! Control-flow IR nodes: Cond, Phi, Isu (§4 "Loops, state, and control
//! flow"). These are what make the *static* graph execute *dynamic*,
//! instance-dependent control flow: they consult only the message state.
//! Version tags and the train flag ride through them untouched — the
//! node runtime threads them, so the glue zoo can no longer break the
//! staleness wire protocol.

use anyhow::{anyhow, Result};

use crate::ir::graph::{Node, PortId};
use crate::ir::rt::NodeCtx;
use crate::ir::state::MsgState;
use crate::tensor::Tensor;

pub type PortFn = Box<dyn Fn(&MsgState) -> usize + Send>;
pub type StateUpdateFn = Box<dyn Fn(&mut MsgState) + Send>;

/// `Cond f`: routes the forward message to output port `f(state)`,
/// querying the *state* (never the payload). Backward messages from any
/// successor return to the single input.
pub struct CondNode {
    label: String,
    predicate: PortFn,
    n_out: usize,
}

impl CondNode {
    pub fn new(label: &str, n_out: usize, predicate: PortFn) -> Self {
        CondNode { label: label.to_string(), predicate, n_out }
    }
}

impl Node for CondNode {
    fn forward(
        &mut self,
        _port: PortId,
        state: MsgState,
        payload: Vec<Tensor>,
        ctx: &mut NodeCtx,
    ) -> Result<()> {
        let out = (self.predicate)(&state);
        anyhow::ensure!(
            out < self.n_out,
            "{}: predicate chose port {out} of {}",
            self.label,
            self.n_out
        );
        ctx.emit_fwd(out, state, payload);
        Ok(())
    }

    fn backward(
        &mut self,
        _port: PortId,
        state: MsgState,
        payload: Vec<Tensor>,
        ctx: &mut NodeCtx,
    ) -> Result<()> {
        ctx.emit_bwd(0, state, payload);
        Ok(())
    }

    fn name(&self) -> &str {
        &self.label
    }
}

/// Origin record of one Phi forward (stashed by the runtime).
struct Origin(PortId);

/// `Phi`: joins several alternative producers into one stream, recording
/// each message's origin port (keyed on state) so the backward pass
/// returns it "to the correct origin" (§4).
pub struct PhiNode {
    label: String,
}

impl PhiNode {
    pub fn new(label: &str) -> Self {
        PhiNode { label: label.to_string() }
    }
}

impl Node for PhiNode {
    fn forward(
        &mut self,
        port: PortId,
        state: MsgState,
        payload: Vec<Tensor>,
        ctx: &mut NodeCtx,
    ) -> Result<()> {
        ctx.stash_bwd(state.key(), Origin(port))
            .map_err(|_| anyhow!("{}: duplicate forward for {:?}", self.label, state))?;
        ctx.emit_fwd(0, state, payload);
        Ok(())
    }

    fn backward(
        &mut self,
        _port: PortId,
        state: MsgState,
        payload: Vec<Tensor>,
        ctx: &mut NodeCtx,
    ) -> Result<()> {
        let Origin(origin) = ctx
            .take(state.key())
            .ok_or_else(|| anyhow!("{}: no recorded origin for {:?}", self.label, state))?;
        ctx.emit_bwd(origin, state, payload);
        Ok(())
    }

    fn name(&self) -> &str {
        &self.label
    }
}

/// `Isu f f⁻¹`: invertible state update. Applies `f` to the state of
/// forward messages and `f⁻¹` to backward messages, so loops execute in
/// both directions (Fig. 2: the time-step increments forward, decrements
/// backward).
pub struct IsuNode {
    label: String,
    f: StateUpdateFn,
    f_inv: StateUpdateFn,
}

impl IsuNode {
    pub fn new(label: &str, f: StateUpdateFn, f_inv: StateUpdateFn) -> Self {
        IsuNode { label: label.to_string(), f, f_inv }
    }

    /// The common loop-counter increment.
    pub fn incr_t(label: &str) -> Self {
        Self::new(
            label,
            Box::new(|s: &mut MsgState| s.t += 1),
            Box::new(|s: &mut MsgState| s.t -= 1),
        )
    }
}

impl Node for IsuNode {
    fn forward(
        &mut self,
        _port: PortId,
        mut state: MsgState,
        payload: Vec<Tensor>,
        ctx: &mut NodeCtx,
    ) -> Result<()> {
        (self.f)(&mut state);
        ctx.emit_fwd(0, state, payload);
        Ok(())
    }

    fn backward(
        &mut self,
        _port: PortId,
        mut state: MsgState,
        payload: Vec<Tensor>,
        ctx: &mut NodeCtx,
    ) -> Result<()> {
        (self.f_inv)(&mut state);
        ctx.emit_bwd(0, state, payload);
        Ok(())
    }

    fn name(&self) -> &str {
        &self.label
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::message::Message;
    use crate::ir::rt::{invoke_msg, NodeRt};
    use crate::runtime::NativeBackend;
    use crate::tensor::Tensor;
    use std::sync::mpsc::channel;

    fn drive(
        node: &mut dyn Node,
        rt: &mut NodeRt,
        port: PortId,
        msg: Message,
    ) -> Result<Vec<(PortId, Message)>> {
        let (tx, _rx) = channel();
        let mut be = NativeBackend::new();
        invoke_msg(node, rt, &mut be, &tx, 0, port, msg)
    }

    #[test]
    fn cond_routes_by_state() {
        let mut n = CondNode::new("c", 2, Box::new(|s| usize::from(s.t >= s.t_max)));
        let mut rt = NodeRt::new();
        let mut s = MsgState::for_instance(1);
        s.t_max = 3;
        s.t = 1;
        let r = drive(&mut n, &mut rt, 0, Message::fwd(s, vec![])).unwrap();
        assert_eq!(r[0].0, 0, "loop branch");
        s.t = 3;
        let r = drive(&mut n, &mut rt, 0, Message::fwd(s, vec![])).unwrap();
        assert_eq!(r[0].0, 1, "exit branch");
        // backward always to the single input
        let r = drive(&mut n, &mut rt, 1, Message::bwd(s, vec![])).unwrap();
        assert_eq!(r[0].0, 0);
    }

    #[test]
    fn phi_remembers_origin_per_state() {
        let mut n = PhiNode::new("phi");
        let mut rt = NodeRt::new();
        let mut s0 = MsgState::for_instance(1);
        let mut s1 = MsgState::for_instance(1);
        s0.t = 0;
        s1.t = 1;
        drive(&mut n, &mut rt, 0, Message::fwd(s0, vec![])).unwrap();
        drive(&mut n, &mut rt, 1, Message::fwd(s1, vec![])).unwrap();
        assert_eq!(rt.cached(), 4, "two origin stashes + two ledger entries");
        let b1 = drive(&mut n, &mut rt, 0, Message::bwd(s1, vec![])).unwrap();
        assert_eq!(b1[0].0, 1);
        let b0 = drive(&mut n, &mut rt, 0, Message::bwd(s0, vec![])).unwrap();
        assert_eq!(b0[0].0, 0);
        assert_eq!(rt.cached(), 0);
    }

    #[test]
    fn phi_eval_mode_caches_nothing() {
        let mut n = PhiNode::new("phi");
        let mut rt = NodeRt::new();
        drive(&mut n, &mut rt, 0, Message::eval(MsgState::for_instance(1), vec![])).unwrap();
        assert_eq!(rt.cached(), 0);
    }

    #[test]
    fn isu_inverts_in_backward() {
        let mut n = IsuNode::incr_t("isu");
        let mut rt = NodeRt::new();
        let mut s = MsgState::for_instance(1);
        s.t = 2;
        let f = drive(&mut n, &mut rt, 0, Message::fwd(s, vec![Tensor::scalar(0.0)])).unwrap();
        assert_eq!(f[0].1.state.t, 3);
        let b = drive(&mut n, &mut rt, 0, Message::bwd(f[0].1.state, vec![])).unwrap();
        assert_eq!(b[0].1.state.t, 2, "f_inv(f(x)) == x");
    }

    #[test]
    fn phi_duplicate_forward_rejected() {
        let mut n = PhiNode::new("phi");
        let mut rt = NodeRt::new();
        let s = MsgState::for_instance(2);
        drive(&mut n, &mut rt, 0, Message::fwd(s, vec![])).unwrap();
        assert!(drive(&mut n, &mut rt, 1, Message::fwd(s, vec![])).is_err());
    }

    #[test]
    fn cond_phi_roundtrip_preserves_version_tags() {
        // Cond -> Phi chain: the tag must survive the round trip in both
        // directions (the ROADMAP's "version tags through glue nodes").
        let mut cond = CondNode::new("c", 2, Box::new(|s| (s.t % 2) as usize));
        let mut phi = PhiNode::new("phi");
        let (mut rt_c, mut rt_p) = (NodeRt::new(), NodeRt::new());
        let mut s = MsgState::for_instance(3);
        s.t = 1;
        let f = drive(&mut cond, &mut rt_c, 0, Message::fwd(s, vec![]).versioned(6)).unwrap();
        assert_eq!(f[0].0, 1);
        assert_eq!(f[0].1.version(), Some(6));
        let f2 = drive(&mut phi, &mut rt_p, f[0].0, f[0].1.clone()).unwrap();
        assert_eq!(f2[0].1.version(), Some(6));
        // echo back through Phi then Cond
        let b = drive(&mut phi, &mut rt_p, 0, Message::bwd(s, vec![]).versioned(6)).unwrap();
        assert_eq!(b[0].0, 1, "returned to the recorded origin");
        assert_eq!(b[0].1.version(), Some(6));
        let b2 = drive(&mut cond, &mut rt_c, b[0].0, b[0].1.clone()).unwrap();
        assert_eq!(b2[0].1.version(), Some(6));
        assert_eq!(rt_c.cached() + rt_p.cached(), 0);
    }
}
