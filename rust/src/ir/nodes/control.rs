//! Control-flow IR nodes: Cond, Phi, Isu (§4 "Loops, state, and control
//! flow"). These are what make the *static* graph execute *dynamic*,
//! instance-dependent control flow: they consult only the message state.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::ir::graph::{Node, NodeCtx, PortId};
use crate::ir::message::Message;
use crate::ir::state::{MsgState, StateKey};

pub type PortFn = Box<dyn Fn(&MsgState) -> usize + Send>;
pub type StateUpdateFn = Box<dyn Fn(&mut MsgState) + Send>;

/// `Cond f`: routes the forward message to output port `f(state)`,
/// querying the *state* (never the payload). Backward messages from any
/// successor return to the single input.
pub struct CondNode {
    label: String,
    predicate: PortFn,
    n_out: usize,
}

impl CondNode {
    pub fn new(label: &str, n_out: usize, predicate: PortFn) -> Self {
        CondNode { label: label.to_string(), predicate, n_out }
    }
}

impl Node for CondNode {
    fn forward(&mut self, _port: PortId, msg: Message, _ctx: &mut NodeCtx) -> Result<Vec<(PortId, Message)>> {
        let out = (self.predicate)(&msg.state);
        anyhow::ensure!(out < self.n_out, "{}: predicate chose port {out} of {}", self.label, self.n_out);
        Ok(vec![(out, msg)])
    }

    fn backward(&mut self, _port: PortId, msg: Message, _ctx: &mut NodeCtx) -> Result<Vec<(PortId, Message)>> {
        Ok(vec![(0, msg)])
    }

    fn name(&self) -> &str {
        &self.label
    }
}

/// `Phi`: joins several alternative producers into one stream, recording
/// each message's origin port (keyed on state) so the backward pass
/// returns it "to the correct origin" (§4).
pub struct PhiNode {
    label: String,
    origins: HashMap<StateKey, PortId>,
}

impl PhiNode {
    pub fn new(label: &str) -> Self {
        PhiNode { label: label.to_string(), origins: HashMap::new() }
    }
}

impl Node for PhiNode {
    fn forward(&mut self, port: PortId, msg: Message, _ctx: &mut NodeCtx) -> Result<Vec<(PortId, Message)>> {
        if msg.train {
            let prev = self.origins.insert(msg.state.key(), port);
            anyhow::ensure!(prev.is_none(), "{}: duplicate forward for {:?}", self.label, msg.state);
        }
        Ok(vec![(0, msg)])
    }

    fn backward(&mut self, _port: PortId, msg: Message, _ctx: &mut NodeCtx) -> Result<Vec<(PortId, Message)>> {
        let origin = self
            .origins
            .remove(&msg.state.key())
            .ok_or_else(|| anyhow!("{}: no recorded origin for {:?}", self.label, msg.state))?;
        Ok(vec![(origin, msg)])
    }

    fn cached_keys(&self) -> usize {
        self.origins.len()
    }

    fn name(&self) -> &str {
        &self.label
    }
}

/// `Isu f f⁻¹`: invertible state update. Applies `f` to the state of
/// forward messages and `f⁻¹` to backward messages, so loops execute in
/// both directions (Fig. 2: the time-step increments forward, decrements
/// backward).
pub struct IsuNode {
    label: String,
    f: StateUpdateFn,
    f_inv: StateUpdateFn,
}

impl IsuNode {
    pub fn new(label: &str, f: StateUpdateFn, f_inv: StateUpdateFn) -> Self {
        IsuNode { label: label.to_string(), f, f_inv }
    }

    /// The common loop-counter increment.
    pub fn incr_t(label: &str) -> Self {
        Self::new(
            label,
            Box::new(|s: &mut MsgState| s.t += 1),
            Box::new(|s: &mut MsgState| s.t -= 1),
        )
    }
}

impl Node for IsuNode {
    fn forward(&mut self, _port: PortId, mut msg: Message, _ctx: &mut NodeCtx) -> Result<Vec<(PortId, Message)>> {
        (self.f)(&mut msg.state);
        Ok(vec![(0, msg)])
    }

    fn backward(&mut self, _port: PortId, mut msg: Message, _ctx: &mut NodeCtx) -> Result<Vec<(PortId, Message)>> {
        (self.f_inv)(&mut msg.state);
        Ok(vec![(0, msg)])
    }

    fn name(&self) -> &str {
        &self.label
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::graph::Event;
    use crate::runtime::NativeBackend;
    use crate::tensor::Tensor;
    use std::sync::mpsc::channel;

    fn ctx<'a>(
        be: &'a mut NativeBackend,
        tx: &'a std::sync::mpsc::Sender<Event>,
    ) -> NodeCtx<'a> {
        NodeCtx { backend: be, events: tx, node_id: 0 }
    }

    #[test]
    fn cond_routes_by_state() {
        let mut n = CondNode::new("c", 2, Box::new(|s| usize::from(s.t >= s.t_max)));
        let (tx, _rx) = channel();
        let mut be = NativeBackend::new();
        let mut c = ctx(&mut be, &tx);
        let mut s = MsgState::for_instance(1);
        s.t_max = 3;
        s.t = 1;
        let r = n.forward(0, Message::fwd(s, vec![]), &mut c).unwrap();
        assert_eq!(r[0].0, 0, "loop branch");
        s.t = 3;
        let r = n.forward(0, Message::fwd(s, vec![]), &mut c).unwrap();
        assert_eq!(r[0].0, 1, "exit branch");
        // backward always to the single input
        let r = n.backward(1, Message::bwd(s, vec![]), &mut c).unwrap();
        assert_eq!(r[0].0, 0);
    }

    #[test]
    fn phi_remembers_origin_per_state() {
        let mut n = PhiNode::new("phi");
        let (tx, _rx) = channel();
        let mut be = NativeBackend::new();
        let mut c = ctx(&mut be, &tx);
        let mut s0 = MsgState::for_instance(1);
        let mut s1 = MsgState::for_instance(1);
        s0.t = 0;
        s1.t = 1;
        n.forward(0, Message::fwd(s0, vec![]), &mut c).unwrap();
        n.forward(1, Message::fwd(s1, vec![]), &mut c).unwrap();
        assert_eq!(n.cached_keys(), 2);
        let b1 = n.backward(0, Message::bwd(s1, vec![]), &mut c).unwrap();
        assert_eq!(b1[0].0, 1);
        let b0 = n.backward(0, Message::bwd(s0, vec![]), &mut c).unwrap();
        assert_eq!(b0[0].0, 0);
        assert_eq!(n.cached_keys(), 0);
    }

    #[test]
    fn phi_eval_mode_caches_nothing() {
        let mut n = PhiNode::new("phi");
        let (tx, _rx) = channel();
        let mut be = NativeBackend::new();
        let mut c = ctx(&mut be, &tx);
        n.forward(0, Message::eval(MsgState::for_instance(1), vec![]), &mut c).unwrap();
        assert_eq!(n.cached_keys(), 0);
    }

    #[test]
    fn isu_inverts_in_backward() {
        let mut n = IsuNode::incr_t("isu");
        let (tx, _rx) = channel();
        let mut be = NativeBackend::new();
        let mut c = ctx(&mut be, &tx);
        let mut s = MsgState::for_instance(1);
        s.t = 2;
        let f = n.forward(0, Message::fwd(s, vec![Tensor::scalar(0.0)]), &mut c).unwrap();
        assert_eq!(f[0].1.state.t, 3);
        let b = n.backward(0, Message::bwd(f[0].1.state, vec![]), &mut c).unwrap();
        assert_eq!(b[0].1.state.t, 2, "f_inv(f(x)) == x");
    }

    #[test]
    fn phi_duplicate_forward_rejected() {
        let mut n = PhiNode::new("phi");
        let (tx, _rx) = channel();
        let mut be = NativeBackend::new();
        let mut c = ctx(&mut be, &tx);
        let s = MsgState::for_instance(2);
        n.forward(0, Message::fwd(s, vec![]), &mut c).unwrap();
        assert!(n.forward(1, Message::fwd(s, vec![]), &mut c).is_err());
    }
}
