//! Message state — the paper's central design choice.
//!
//! "We encapsulate the state with which a message should be processed
//! through the graph in the message itself" (§7). The state carries the
//! instance id, loop counters and structural positions; PPT/Phi/Group/
//! Flatmap nodes *key* their per-message caches on it, which is what lets
//! a single static node process interleaved messages from many instances
//! at once without conflating activations.

/// Algorithmic state attached to every message. Fields are model-specific
/// in meaning but shared in layout so the runtime stays generic:
/// `instance` (and `replica`) identify the in-flight key, `t` is a loop
/// counter (RNN position / GNN propagation step), `node`/`edge`/`etype`
/// locate a message inside an instance's structure, and `aux` carries a
/// model-defined cardinality (e.g. #nodes of a graph instance).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct MsgState {
    pub instance: u64,
    pub replica: u16,
    pub t: u32,
    pub t_max: u32,
    pub node: u32,
    pub edge: u32,
    pub etype: u8,
    pub aux: u32,
}

impl MsgState {
    /// State for a fresh instance.
    pub fn for_instance(instance: u64) -> Self {
        MsgState { instance, ..Default::default() }
    }

    /// The caching key. The full state participates: the paper's invariant
    /// is that the backward message carries *the same state* as the
    /// forward message, so keying on all of it is always safe.
    pub fn key(&self) -> StateKey {
        StateKey(*self)
    }
}

/// Hash key wrapper (distinct type so APIs can't confuse state and key).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct StateKey(pub MsgState);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_equality_tracks_full_state() {
        let a = MsgState { instance: 1, t: 3, ..Default::default() };
        let mut b = a;
        assert_eq!(a.key(), b.key());
        b.t = 4;
        assert_ne!(a.key(), b.key());
        b.t = 3;
        b.node = 9;
        assert_ne!(a.key(), b.key());
    }
}
