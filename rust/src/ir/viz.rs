//! IR graph visualization: Graphviz DOT emission, a terminal summary, and
//! the per-worker placement histogram. The paper's Figs. 2, 4 and 7 are
//! exactly these graphs.

use super::graph::Graph;

/// Render the IR graph as Graphviz DOT. Solid edges are the forward
/// dataflow; controller-pumped inputs and controller-bound backward
/// boundaries are implicit (dangling ports listed in the node tooltip).
pub fn to_dot(graph: &Graph) -> String {
    let mut out = String::from("digraph ampnet {\n  rankdir=LR;\n  node [shape=box, fontsize=10];\n");
    for (id, slot) in graph.nodes.iter().enumerate() {
        out.push_str(&format!(
            "  n{id} [label=\"{}\\n#{} w{}\"];\n",
            slot.label, id, slot.worker
        ));
    }
    for (src, ports) in graph.fwd_edges.iter().enumerate() {
        for (port, tgt) in ports.iter().enumerate() {
            if let Some((dst, dport)) = tgt {
                out.push_str(&format!(
                    "  n{src} -> n{dst} [label=\"{port}->{dport}\", fontsize=8];\n"
                ));
            }
        }
    }
    out.push_str("}\n");
    out
}

/// One-line-per-node terminal summary (used by `ampnet inspect --graph`).
pub fn summary(graph: &Graph) -> String {
    let mut out = String::new();
    for (id, slot) in graph.nodes.iter().enumerate() {
        let outs: Vec<String> = graph.fwd_edges[id]
            .iter()
            .enumerate()
            .filter_map(|(p, t)| t.map(|(d, dp)| format!("{p}->{}:{dp}", graph.nodes[d].label)))
            .collect();
        out.push_str(&format!(
            "#{id:<3} w{:<2} {:<18} -> [{}]\n",
            slot.worker,
            slot.label,
            outs.join(", ")
        ));
    }
    out
}

/// Compact nodes-per-worker histogram, e.g. `w0:3 w1:2 w5:9` (idle
/// workers omitted). `ampnet inspect --graph` prints one line per
/// placement strategy so placement regressions show up in CLI diffs.
pub fn worker_histogram(graph: &Graph) -> String {
    let mut counts = vec![0usize; graph.n_workers];
    for slot in &graph.nodes {
        counts[slot.worker] += 1;
    }
    counts
        .iter()
        .enumerate()
        .filter(|(_, &c)| c > 0)
        .map(|(w, c)| format!("w{w}:{c}"))
        .collect::<Vec<_>>()
        .join(" ")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MnistLike;
    use crate::ir::{NetBuilder, NodeSpec, Pinned, PlacementKind};
    use crate::models::{mlp, ModelCfg};

    #[test]
    fn dot_contains_every_node_and_edge() {
        let model =
            mlp::build(&ModelCfg::default(), MnistLike::new(0, 100, 100, 100), 4).unwrap();
        let dot = to_dot(&model.graph);
        assert!(dot.contains("linear-1"));
        assert!(dot.contains("loss"));
        // 3 pipeline edges + head->loss
        assert_eq!(dot.matches(" -> ").count(), 3);
        let s = summary(&model.graph);
        assert!(s.lines().count() >= 4);
    }

    /// Snapshot-style check of dot/summary/histogram over a small
    /// NetBuilder-built graph with explicit pins.
    #[test]
    fn renders_netbuilder_output_with_worker_annotations() {
        use crate::ir::build::testing::Dummy;

        let mut b = NetBuilder::new();
        let enc = b.add(NodeSpec::new("encoder").pin(0), Box::new(Dummy));
        let dec = b.add(NodeSpec::new("decoder").pin(2).outputs(0), Box::new(Dummy));
        b.wire(enc.out(0), dec.input(0));
        b.controller_input(enc.input(0));
        let net = b.build(3, &Pinned).unwrap();

        let dot = to_dot(&net.graph);
        assert!(dot.contains("encoder\\n#0 w0"), "node label + worker annotation:\n{dot}");
        assert!(dot.contains("decoder\\n#1 w2"), "{dot}");
        assert_eq!(dot.matches(" -> ").count(), 1, "{dot}");
        assert!(dot.contains("[label=\"0->0\""), "edge port annotation:\n{dot}");

        let s = summary(&net.graph);
        assert_eq!(s.lines().count(), 2, "{s}");
        assert!(s.contains("w0") && s.contains("w2"), "{s}");
        assert!(s.contains("0->decoder:0"), "{s}");

        assert_eq!(worker_histogram(&net.graph), "w0:1 w2:1");
    }

    #[test]
    fn histogram_reflects_placement_strategy() {
        let build_with = |kind: PlacementKind| {
            let mut cfg = ModelCfg::default();
            cfg.placement = kind;
            mlp::build(&cfg, MnistLike::new(0, 100, 100, 100), 2).unwrap()
        };
        // mlp pins are i % n_workers, so pinned == round-robin here…
        assert_eq!(
            worker_histogram(&build_with(PlacementKind::Pinned).graph),
            worker_histogram(&build_with(PlacementKind::RoundRobin).graph),
        );
        // …and the cost-aware LPT greedy is deterministic: linears spread
        // heaviest-first, the zero-cost loss joins the lighter worker —
        // the exact line CLI diffs key on. (A strategy regression that
        // piles everything onto one worker breaks this.)
        assert_eq!(
            worker_histogram(&build_with(PlacementKind::Cost).graph),
            "w0:2 w1:2"
        );
    }
}
