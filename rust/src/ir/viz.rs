//! IR graph visualization: Graphviz DOT emission and a terminal summary.
//! The paper's Figs. 2, 4 and 7 are exactly these graphs.

use super::graph::Graph;

/// Render the IR graph as Graphviz DOT. Solid edges are the forward
/// dataflow; controller-pumped inputs and controller-bound backward
/// boundaries are implicit (dangling ports listed in the node tooltip).
pub fn to_dot(graph: &Graph) -> String {
    let mut out = String::from("digraph ampnet {\n  rankdir=LR;\n  node [shape=box, fontsize=10];\n");
    for (id, slot) in graph.nodes.iter().enumerate() {
        out.push_str(&format!(
            "  n{id} [label=\"{}\\n#{} w{}\"];\n",
            slot.label, id, slot.worker
        ));
    }
    for (src, ports) in graph.fwd_edges.iter().enumerate() {
        for (port, tgt) in ports.iter().enumerate() {
            if let Some((dst, dport)) = tgt {
                out.push_str(&format!(
                    "  n{src} -> n{dst} [label=\"{port}->{dport}\", fontsize=8];\n"
                ));
            }
        }
    }
    out.push_str("}\n");
    out
}

/// One-line-per-node terminal summary (used by `ampnet inspect --graph`).
pub fn summary(graph: &Graph) -> String {
    let mut out = String::new();
    for (id, slot) in graph.nodes.iter().enumerate() {
        let outs: Vec<String> = graph.fwd_edges[id]
            .iter()
            .enumerate()
            .filter_map(|(p, t)| t.map(|(d, dp)| format!("{p}->{}:{dp}", graph.nodes[d].label)))
            .collect();
        out.push_str(&format!(
            "#{id:<3} w{:<2} {:<18} -> [{}]\n",
            slot.worker,
            slot.label,
            outs.join(", ")
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MnistLike;
    use crate::models::{mlp, ModelCfg};

    #[test]
    fn dot_contains_every_node_and_edge() {
        let model = mlp::build(&ModelCfg::default(), MnistLike::new(0, 100, 100, 100), 4);
        let dot = to_dot(&model.graph);
        assert!(dot.contains("linear-1"));
        assert!(dot.contains("loss"));
        // 3 pipeline edges + head->loss
        assert_eq!(dot.matches(" -> ").count(), 3);
        let s = summary(&model.graph);
        assert!(s.lines().count() >= 4);
    }
}
