//! Typed graph-construction API: [`NetBuilder`] wires nodes through typed
//! port handles ([`OutPort`]/[`InPort`]), carries declarative per-node
//! metadata ([`NodeSpec`]: port arities, placement pins, FLOP estimates,
//! known port dims), and separates *worker assignment* from *topology*
//! through the pluggable [`Placement`] trait.
//!
//! `build()` runs a real validation pass and returns `Result<Net>`:
//!
//! * every declared input port is either wired or registered as a
//!   controller pump via [`NetBuilder::controller_input`];
//! * no dangling or doubly-wired output ports;
//! * port feature dims agree wherever both endpoints declare one;
//! * the placement strategy assigned every node a worker in range.
//!
//! (The legacy `GraphBuilder` shim — raw `(NodeId, PortId)` wiring,
//! panicking asserts, no validation — has been deleted; every builder
//! goes through this API.)

use anyhow::{bail, ensure, Result};

use super::graph::{Graph, Node, NodeId, NodeSlot, PortId, WorkerId};

/// Handle to a node added to a [`NetBuilder`]. Carries typed port
/// accessors so call sites never touch raw `(NodeId, PortId)` pairs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NodeHandle {
    id: NodeId,
}

impl NodeHandle {
    pub fn id(&self) -> NodeId {
        self.id
    }

    /// Typed handle to output port `port` of this node.
    pub fn out(&self, port: PortId) -> OutPort {
        OutPort { node: self.id, port }
    }

    /// Typed handle to input port `port` of this node.
    pub fn input(&self, port: PortId) -> InPort {
        InPort { node: self.id, port }
    }
}

/// An output port of a specific node (forward messages flow out of it).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OutPort {
    pub node: NodeId,
    pub port: PortId,
}

/// An input port of a specific node (forward messages flow into it).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct InPort {
    pub node: NodeId,
    pub port: PortId,
}

/// Declarative per-node metadata consumed by validation and placement.
#[derive(Clone, Debug)]
pub struct NodeSpec {
    pub label: String,
    /// Number of input ports (forward messages in / backward messages out).
    pub n_inputs: usize,
    /// Number of output ports. Terminal nodes (loss, dead-ends) declare 0.
    pub n_outputs: usize,
    /// Relative per-invocation cost estimate in FLOPs, consumed by
    /// cost-aware placement. Control/glue nodes leave it at 0.
    pub cost: u64,
    /// Preferred worker. Authoritative under [`Pinned`]; a *hint* other
    /// strategies are free to ignore.
    pub pin: Option<WorkerId>,
    /// Known feature dim per input port (`None` = unchecked). Checked
    /// against the producer's `out_dims` at build time.
    pub in_dims: Vec<Option<usize>>,
    /// Known feature dim per output port.
    pub out_dims: Vec<Option<usize>>,
}

impl NodeSpec {
    /// A 1-in/1-out node with no cost estimate, no pin, unchecked dims.
    pub fn new(label: &str) -> Self {
        NodeSpec {
            label: label.to_string(),
            n_inputs: 1,
            n_outputs: 1,
            cost: 0,
            pin: None,
            in_dims: Vec::new(),
            out_dims: Vec::new(),
        }
    }

    pub fn inputs(mut self, n: usize) -> Self {
        self.n_inputs = n;
        self
    }

    pub fn outputs(mut self, n: usize) -> Self {
        self.n_outputs = n;
        self
    }

    pub fn cost(mut self, flops: u64) -> Self {
        self.cost = flops;
        self
    }

    pub fn pin(mut self, worker: WorkerId) -> Self {
        self.pin = Some(worker);
        self
    }

    pub fn in_dim(mut self, port: PortId, dim: usize) -> Self {
        if self.in_dims.len() <= port {
            self.in_dims.resize(port + 1, None);
        }
        self.in_dims[port] = Some(dim);
        self
    }

    pub fn out_dim(mut self, port: PortId, dim: usize) -> Self {
        if self.out_dims.len() <= port {
            self.out_dims.resize(port + 1, None);
        }
        self.out_dims[port] = Some(dim);
        self
    }
}

// ====================================================== placement ======

/// A worker-assignment strategy: maps node metadata to a worker per node.
/// Decoupled from topology so `--placement` is a CLI/bench axis (AMP-style
/// pluggable placement; PipeMare-style pipeline-depth experiments slot in
/// as new impls without touching any model builder).
pub trait Placement {
    fn name(&self) -> &'static str;

    /// Assign a worker to every node (same order as `specs`). Returned
    /// ids are validated against `n_workers` by `NetBuilder::build`.
    fn assign(&self, specs: &[NodeSpec], n_workers: usize) -> Vec<WorkerId>;
}

/// Nodes cycle over workers in insertion order, ignoring pins.
pub struct RoundRobin;

impl Placement for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn assign(&self, specs: &[NodeSpec], n_workers: usize) -> Vec<WorkerId> {
        (0..specs.len()).map(|i| i % n_workers).collect()
    }
}

/// Honors each node's `pin` (the model's hand-tuned affinitization — the
/// paper's per-model layout). Unpinned nodes fall back to round-robin.
pub struct Pinned;

impl Placement for Pinned {
    fn name(&self) -> &'static str {
        "pinned"
    }

    fn assign(&self, specs: &[NodeSpec], n_workers: usize) -> Vec<WorkerId> {
        specs
            .iter()
            .enumerate()
            .map(|(i, s)| s.pin.unwrap_or(i % n_workers))
            .collect()
    }
}

/// Cost-aware placement: longest-processing-time greedy over per-node
/// costs — heaviest node first, each onto the currently least-loaded
/// worker. Pins are ignored; zero-cost glue nodes all land on the
/// least-loaded worker, naturally colocating control flow.
///
/// The cost source is `measured` (per-node calibrated costs from a
/// [`crate::placement::CostProfile`]) when provided, falling back to
/// the specs' static FLOP estimates — one LPT code path whether the
/// numbers came from a profiler or from the model author.
#[derive(Default)]
pub struct CostAware {
    /// Per-node measured costs (same index space as `specs`); `None`
    /// or a missing index falls back to `NodeSpec::cost`.
    pub measured: Option<Vec<u64>>,
}

impl CostAware {
    pub fn measured(costs: Vec<u64>) -> Self {
        CostAware { measured: Some(costs) }
    }

    fn cost_of(&self, specs: &[NodeSpec], i: usize) -> u64 {
        match &self.measured {
            Some(m) => m.get(i).copied().unwrap_or(specs[i].cost),
            None => specs[i].cost,
        }
    }
}

impl Placement for CostAware {
    fn name(&self) -> &'static str {
        "cost"
    }

    fn assign(&self, specs: &[NodeSpec], n_workers: usize) -> Vec<WorkerId> {
        let mut order: Vec<usize> = (0..specs.len()).collect();
        // Stable sort: heaviest first, insertion order among equals.
        order.sort_by_key(|&i| std::cmp::Reverse(self.cost_of(specs, i)));
        let mut load = vec![0u64; n_workers];
        let mut assignment = vec![0; specs.len()];
        for i in order {
            let w = (0..n_workers).min_by_key(|&w| (load[w], w)).unwrap_or(0);
            assignment[i] = w;
            load[w] += self.cost_of(specs, i);
        }
        assignment
    }
}

/// A fully explicit per-node assignment (index-aligned with the specs),
/// e.g. the winner of a placement search loaded from a pinned-placement
/// file (`--placement pinned:<path>`). Out-of-range workers are caught
/// by `NetBuilder::build`'s range validation; a length mismatch is
/// caught by its one-worker-per-node check.
pub struct ExplicitPlacement(pub Vec<WorkerId>);

impl Placement for ExplicitPlacement {
    fn name(&self) -> &'static str {
        "explicit"
    }

    fn assign(&self, _specs: &[NodeSpec], _n_workers: usize) -> Vec<WorkerId> {
        self.0.clone()
    }
}

/// CLI-facing selector for the built-in strategies.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PlacementKind {
    RoundRobin,
    /// The models' hand-tuned per-node layout (paper's affinitization).
    #[default]
    Pinned,
    /// FLOP-estimate-driven longest-processing-time greedy.
    Cost,
}

impl PlacementKind {
    pub const ALL: [PlacementKind; 3] =
        [PlacementKind::RoundRobin, PlacementKind::Pinned, PlacementKind::Cost];

    pub fn strategy(&self) -> Box<dyn Placement> {
        match self {
            PlacementKind::RoundRobin => Box::new(RoundRobin),
            PlacementKind::Pinned => Box::new(Pinned),
            PlacementKind::Cost => Box::new(CostAware::default()),
        }
    }
}

impl std::str::FromStr for PlacementKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "round-robin" | "rr" => Ok(PlacementKind::RoundRobin),
            "pinned" => Ok(PlacementKind::Pinned),
            "cost" | "cost-aware" => Ok(PlacementKind::Cost),
            other => bail!("unknown placement '{other}' (round-robin|pinned|cost)"),
        }
    }
}

impl std::fmt::Display for PlacementKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            PlacementKind::RoundRobin => "round-robin",
            PlacementKind::Pinned => "pinned",
            PlacementKind::Cost => "cost",
        };
        write!(f, "{s}")
    }
}

// ======================================================== builder ======

/// A validated, placed graph plus the replica groups declared on the
/// builder (end-of-epoch parameter averaging, paper §5).
pub struct Net {
    pub graph: Graph,
    pub replica_groups: Vec<Vec<NodeId>>,
}

/// Fluent, validating graph builder. See the module docs for the checks
/// `build()` performs; all errors are deferred to `build()` so model code
/// gets `Result` instead of panics.
#[derive(Default)]
pub struct NetBuilder {
    nodes: Vec<Box<dyn Node>>,
    specs: Vec<NodeSpec>,
    edges: Vec<(OutPort, InPort)>,
    pump_ports: Vec<InPort>,
    replica_groups: Vec<Vec<NodeId>>,
}

impl NetBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a node described by `spec`. Returns its typed handle.
    pub fn add(&mut self, spec: NodeSpec, node: Box<dyn Node>) -> NodeHandle {
        let id = self.nodes.len();
        self.nodes.push(node);
        self.specs.push(spec);
        NodeHandle { id }
    }

    /// Connect `from` to `to`: forward messages flow from→to, backward
    /// messages to→from. Duplicate or out-of-range wiring is reported by
    /// `build()`.
    pub fn wire(&mut self, from: OutPort, to: InPort) {
        self.edges.push((from, to));
    }

    /// Declare that `to` is pumped by the controller. Recorded and
    /// enforced: an input port that is neither wired nor declared here
    /// fails `build()`.
    pub fn controller_input(&mut self, to: InPort) {
        self.pump_ports.push(to);
    }

    /// Declare a replica group (members' parameters are averaged at the
    /// end of each epoch, §5).
    pub fn replica_group(&mut self, members: &[NodeHandle]) {
        self.replica_groups.push(members.iter().map(|h| h.id).collect());
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    fn label(&self, node: NodeId) -> &str {
        &self.specs[node].label
    }

    /// Assign workers via `placement`, validate the wiring, and produce
    /// the runnable [`Graph`].
    pub fn build(self, n_workers: usize, placement: &dyn Placement) -> Result<Net> {
        ensure!(n_workers > 0, "n_workers must be > 0");
        ensure!(!self.nodes.is_empty(), "empty graph");

        let workers = placement.assign(&self.specs, n_workers);
        ensure!(
            workers.len() == self.nodes.len(),
            "placement '{}' assigned {} workers for {} nodes",
            placement.name(),
            workers.len(),
            self.nodes.len()
        );
        for (id, &w) in workers.iter().enumerate() {
            ensure!(
                w < n_workers,
                "placement '{}' put node '{}' (#{id}) on worker {w}, but only {n_workers} workers exist",
                placement.name(),
                self.label(id)
            );
        }

        let n = self.nodes.len();
        let mut fwd: Vec<Vec<Option<(NodeId, PortId)>>> =
            self.specs.iter().map(|s| vec![None; s.n_outputs]).collect();
        let mut bwd: Vec<Vec<Option<(NodeId, PortId)>>> =
            self.specs.iter().map(|s| vec![None; s.n_inputs]).collect();
        let mut pumped: Vec<Vec<bool>> =
            self.specs.iter().map(|s| vec![false; s.n_inputs]).collect();

        for &InPort { node, port } in &self.pump_ports {
            ensure!(node < n, "controller input references unknown node #{node}");
            ensure!(
                port < self.specs[node].n_inputs,
                "controller input port {port} of '{}' (#{node}) out of range (node declares {} inputs)",
                self.label(node),
                self.specs[node].n_inputs
            );
            ensure!(
                !pumped[node][port],
                "controller input port {port} of '{}' (#{node}) declared twice",
                self.label(node),
            );
            pumped[node][port] = true;
        }

        for &(from, to) in &self.edges {
            ensure!(from.node < n, "edge from unknown node #{}", from.node);
            ensure!(to.node < n, "edge to unknown node #{}", to.node);
            let (src, dst) = (&self.specs[from.node], &self.specs[to.node]);
            ensure!(
                from.port < src.n_outputs,
                "output port {} of '{}' (#{}) out of range (node declares {} outputs)",
                from.port,
                src.label,
                from.node,
                src.n_outputs
            );
            ensure!(
                to.port < dst.n_inputs,
                "input port {} of '{}' (#{}) out of range (node declares {} inputs)",
                to.port,
                dst.label,
                to.node,
                dst.n_inputs
            );
            ensure!(
                fwd[from.node][from.port].is_none(),
                "output port {} of '{}' (#{}) wired twice",
                from.port,
                src.label,
                from.node
            );
            ensure!(
                bwd[to.node][to.port].is_none(),
                "input port {} of '{}' (#{}) wired twice",
                to.port,
                dst.label,
                to.node
            );
            ensure!(
                !pumped[to.node][to.port],
                "input port {} of '{}' (#{}) is wired AND declared as a controller input",
                to.port,
                dst.label,
                to.node
            );
            // Port-shape consistency where both endpoints declare a dim.
            if let (Some(Some(od)), Some(Some(id))) =
                (src.out_dims.get(from.port), dst.in_dims.get(to.port))
            {
                ensure!(
                    od == id,
                    "shape mismatch on edge '{}'.{} -> '{}'.{}: producer dim {od} != consumer dim {id}",
                    src.label,
                    from.port,
                    dst.label,
                    to.port
                );
            }
            fwd[from.node][from.port] = Some((to.node, to.port));
            bwd[to.node][to.port] = Some((from.node, from.port));
        }

        // Completeness: every declared port is accounted for.
        for (id, spec) in self.specs.iter().enumerate() {
            for p in 0..spec.n_inputs {
                ensure!(
                    bwd[id][p].is_some() || pumped[id][p],
                    "input port {p} of '{}' (#{id}) is neither wired nor declared as a controller input",
                    spec.label
                );
            }
            for p in 0..spec.n_outputs {
                ensure!(
                    fwd[id][p].is_some(),
                    "output port {p} of '{}' (#{id}) dangles (declare fewer outputs or wire it)",
                    spec.label
                );
            }
        }

        let nodes: Vec<NodeSlot> = self
            .nodes
            .into_iter()
            .zip(self.specs.iter())
            .zip(workers.iter())
            .map(|((node, spec), &worker)| NodeSlot {
                node,
                rt: crate::ir::rt::NodeRt::new(),
                worker,
                label: spec.label.clone(),
                cost: spec.cost,
            })
            .collect();

        Ok(Net {
            graph: Graph { nodes, fwd_edges: fwd, bwd_edges: bwd, n_workers },
            replica_groups: self.replica_groups,
        })
    }
}

/// Test support shared across `ir` unit tests: a pass-through node.
#[cfg(test)]
pub(crate) mod testing {
    use super::*;
    use crate::ir::rt::NodeCtx;
    use crate::ir::state::MsgState;
    use crate::tensor::Tensor;

    pub(crate) struct Dummy;

    impl Node for Dummy {
        fn forward(
            &mut self,
            _p: PortId,
            s: MsgState,
            payload: Vec<Tensor>,
            c: &mut NodeCtx,
        ) -> Result<()> {
            c.emit_fwd(0, s, payload);
            Ok(())
        }
        fn backward(
            &mut self,
            _p: PortId,
            s: MsgState,
            payload: Vec<Tensor>,
            c: &mut NodeCtx,
        ) -> Result<()> {
            c.emit_bwd(0, s, payload);
            Ok(())
        }
        fn name(&self) -> &str {
            "dummy"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testing::Dummy;
    use super::*;

    fn two_node_net() -> (NetBuilder, NodeHandle, NodeHandle) {
        let mut b = NetBuilder::new();
        let a = b.add(NodeSpec::new("a").cost(100), Box::new(Dummy));
        let z = b.add(NodeSpec::new("z").outputs(0), Box::new(Dummy));
        (b, a, z)
    }

    #[test]
    fn wires_both_directions_and_places() {
        let (mut b, a, z) = two_node_net();
        b.wire(a.out(0), z.input(0));
        b.controller_input(a.input(0));
        let net = b.build(2, &RoundRobin).unwrap();
        let g = &net.graph;
        use crate::ir::message::Dir;
        use crate::ir::graph::Endpoint;
        assert_eq!(g.resolve(a.id(), 0, Dir::Fwd), Endpoint::Node(z.id(), 0));
        assert_eq!(g.resolve(z.id(), 0, Dir::Bwd), Endpoint::Node(a.id(), 0));
        assert_eq!(g.resolve(a.id(), 0, Dir::Bwd), Endpoint::Controller);
        assert_eq!(g.worker_of(a.id()), 0);
        assert_eq!(g.worker_of(z.id()), 1);
    }

    /// Regression for the old `GraphBuilder::controller_input`, which
    /// claimed to record pump ports "for validation" but recorded nothing:
    /// an input port that is neither wired nor declared must fail build().
    #[test]
    fn unwired_undeclared_input_fails_build() {
        let (mut b, a, z) = two_node_net();
        b.wire(a.out(0), z.input(0));
        // a.input(0) intentionally neither wired nor declared
        let err = b.build(2, &RoundRobin).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("neither wired nor declared"),
            "wrong diagnosis: {msg}"
        );
        assert!(msg.contains("'a'"), "should name the node: {msg}");
    }

    #[test]
    fn dangling_output_fails_build() {
        let (mut b, a, z) = two_node_net();
        b.controller_input(a.input(0));
        b.controller_input(z.input(0));
        let err = b.build(2, &RoundRobin).unwrap_err();
        assert!(format!("{err:#}").contains("dangles"), "{err:#}");
        assert_eq!(a.id(), 0);
    }

    #[test]
    fn double_wiring_fails_build() {
        let mut b = NetBuilder::new();
        let a = b.add(NodeSpec::new("a"), Box::new(Dummy));
        let y = b.add(NodeSpec::new("y").inputs(2).outputs(0), Box::new(Dummy));
        b.wire(a.out(0), y.input(0));
        b.wire(a.out(0), y.input(1));
        b.controller_input(a.input(0));
        let err = b.build(1, &RoundRobin).unwrap_err();
        assert!(format!("{err:#}").contains("wired twice"), "{err:#}");
    }

    #[test]
    fn pumped_and_wired_port_fails_build() {
        let (mut b, a, z) = two_node_net();
        b.wire(a.out(0), z.input(0));
        b.controller_input(a.input(0));
        b.controller_input(z.input(0));
        let err = b.build(1, &RoundRobin).unwrap_err();
        assert!(format!("{err:#}").contains("wired AND declared"), "{err:#}");
    }

    #[test]
    fn out_of_range_port_fails_build() {
        let (mut b, a, z) = two_node_net();
        b.wire(a.out(3), z.input(0));
        b.controller_input(a.input(0));
        let err = b.build(1, &RoundRobin).unwrap_err();
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
    }

    #[test]
    fn shape_mismatch_fails_build() {
        let mut b = NetBuilder::new();
        let a = b.add(NodeSpec::new("enc").out_dim(0, 128), Box::new(Dummy));
        let z = b
            .add(NodeSpec::new("head").in_dim(0, 64).outputs(0), Box::new(Dummy));
        b.wire(a.out(0), z.input(0));
        b.controller_input(a.input(0));
        let err = b.build(1, &RoundRobin).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("shape mismatch"), "{msg}");
        assert!(msg.contains("128") && msg.contains("64"), "{msg}");
    }

    #[test]
    fn pinned_out_of_range_fails_build() {
        let mut b = NetBuilder::new();
        let a = b.add(NodeSpec::new("a").pin(9).outputs(0), Box::new(Dummy));
        b.controller_input(a.input(0));
        let err = b.build(2, &Pinned).unwrap_err();
        assert!(format!("{err:#}").contains("worker 9"), "{err:#}");
    }

    #[test]
    fn cost_aware_spreads_heavy_and_colocates_glue() {
        let mut b = NetBuilder::new();
        let h1 = b.add(NodeSpec::new("h1").cost(1000), Box::new(Dummy));
        let h2 = b.add(NodeSpec::new("h2").cost(900), Box::new(Dummy));
        let g1 = b.add(NodeSpec::new("g1"), Box::new(Dummy));
        let g2 = b.add(NodeSpec::new("g2").outputs(0), Box::new(Dummy));
        b.wire(h1.out(0), h2.input(0));
        b.wire(h2.out(0), g1.input(0));
        b.wire(g1.out(0), g2.input(0));
        b.controller_input(h1.input(0));
        let net = b.build(4, &CostAware::default()).unwrap();
        let w: Vec<_> = net.graph.nodes.iter().map(|s| s.worker).collect();
        assert_ne!(w[0], w[1], "heavy nodes must spread");
        assert_eq!(w[2], w[3], "zero-cost glue colocates");
    }

    #[test]
    fn measured_costs_override_static_estimates() {
        let mut b = NetBuilder::new();
        // Static estimates say h1 is the heavy node; the measured profile
        // says h2 is. LPT over measured costs must spread them and seed
        // from the measured ordering.
        let h1 = b.add(NodeSpec::new("h1").cost(1000), Box::new(Dummy));
        let h2 = b.add(NodeSpec::new("h2").cost(1).outputs(0), Box::new(Dummy));
        b.wire(h1.out(0), h2.input(0));
        b.controller_input(h1.input(0));
        let specs_snapshot =
            [NodeSpec::new("h1").cost(1000), NodeSpec::new("h2").cost(1)];
        let measured = CostAware::measured(vec![1, 1000]);
        let w = measured.assign(&specs_snapshot, 2);
        // Heaviest-first: h2 (measured 1000) goes to worker 0, h1 to 1.
        assert_eq!(w, vec![1, 0]);
        // Fallback: a too-short measured vec uses the static estimate.
        let partial = CostAware::measured(vec![5]);
        assert_eq!(partial.cost_of(&specs_snapshot, 1), 1);
        let net = b.build(2, &measured).unwrap();
        assert_ne!(net.graph.nodes[0].worker, net.graph.nodes[1].worker);
    }

    #[test]
    fn explicit_placement_and_set_workers_roundtrip() {
        let (mut b, a, z) = two_node_net();
        b.wire(a.out(0), z.input(0));
        b.controller_input(a.input(0));
        let net = b.build(4, &ExplicitPlacement(vec![3, 1])).unwrap();
        let mut g = net.graph;
        assert_eq!(g.worker_of(a.id()), 3);
        assert_eq!(g.worker_of(z.id()), 1);
        assert_eq!(g.nodes[a.id()].cost, 100, "spec cost survives build");
        g.set_workers(&[0, 2]);
        assert_eq!(g.worker_of(a.id()), 0);
        assert_eq!(g.worker_of(z.id()), 2);
    }

    #[test]
    fn explicit_placement_out_of_range_fails_build() {
        let (mut b, a, z) = two_node_net();
        b.wire(a.out(0), z.input(0));
        b.controller_input(a.input(0));
        let err = b.build(2, &ExplicitPlacement(vec![0, 5])).unwrap_err();
        assert!(format!("{err:#}").contains("worker 5"), "{err:#}");
    }

    #[test]
    fn replica_groups_flow_through() {
        let mut b = NetBuilder::new();
        let a = b.add(NodeSpec::new("r0"), Box::new(Dummy));
        let c = b.add(NodeSpec::new("r1"), Box::new(Dummy));
        let z = b.add(NodeSpec::new("z").inputs(2).outputs(0), Box::new(Dummy));
        b.wire(a.out(0), z.input(0));
        b.wire(c.out(0), z.input(1));
        b.controller_input(a.input(0));
        b.controller_input(c.input(0));
        b.replica_group(&[a, c]);
        let net = b.build(2, &Pinned).unwrap();
        assert_eq!(net.replica_groups, vec![vec![a.id(), c.id()]]);
    }

    #[test]
    fn placement_kind_parses_and_prints() {
        for kind in PlacementKind::ALL {
            let roundtrip: PlacementKind = kind.to_string().parse().unwrap();
            assert_eq!(roundtrip, kind);
        }
        assert!("nope".parse::<PlacementKind>().is_err());
        assert_eq!("rr".parse::<PlacementKind>().unwrap(), PlacementKind::RoundRobin);
    }
}
