//! The node runtime (DESIGN.md §10): everything cross-cutting that every
//! IR node used to hand-roll — metadata propagation, per-instance
//! caching, eval-mode skipping — owned in one place.
//!
//! A node invocation never sees a [`Message`]. The engines decompose the
//! incoming message into `(port, state, payload)` and hand the node a
//! [`NodeCtx`]; the node emits outputs through [`NodeCtx::emit_fwd`] /
//! [`NodeCtx::emit_bwd`] and parks per-instance data in the runtime's
//! typed stash ([`NodeCtx::stash`] / [`NodeCtx::take`]). The runtime
//! threads [`MsgMeta`] fwd→cache→bwd around the node:
//!
//! * **forward in** — the incoming metadata seeds the invocation's
//!   accumulator; every `take` of stashed data merges the metadata that
//!   was recorded when that data was stashed (so multi-input joins
//!   combine `lane` by severity rank and `param_version` by max without
//!   the node knowing the tags exist);
//! * **forward out** — `emit_fwd` attaches the accumulated metadata,
//!   stamps the node's own [`Node::version`] over the version tag if the
//!   node is parameterized, and (train only) records the pre-stamp
//!   upstream metadata keyed by the *output* state;
//! * **backward in** — the runtime consumes that record (each forward
//!   output receives exactly one backward with the same state — the
//!   paper's §4 invariant, which also makes the ledger leak-free), so
//!   `emit_bwd` echoes each input port's original producer tag upstream
//!   and [`NodeCtx::fwd_version`] hands parameterized nodes the version
//!   their forward pass ran at — the runtime's own record is
//!   authoritative (a downstream join may have max-merged the echo with
//!   a parallel branch's tag), the incoming echo is the fallback for
//!   untracked states — for exact staleness measurement.

use std::any::Any;
use std::collections::HashMap;

use anyhow::{anyhow, ensure, Result};

use crate::runtime::Backend;
use crate::tensor::Tensor;

use super::graph::{Event, EventSink, Node, NodeId, PortId};
use super::message::{Dir, Lane, Message, MsgMeta};
use super::state::{MsgState, StateKey};

/// Invocation-scoped metadata accumulator: the merged view plus the
/// per-input-port tags (so backward echoes are per-port exact where the
/// inputs are distinguishable, falling back to the merged max).
#[derive(Clone, Debug)]
pub struct MetaAcc {
    merged: MsgMeta,
    ports: Vec<(PortId, MsgMeta)>,
}

impl MetaAcc {
    fn from_port(port: PortId, meta: MsgMeta) -> Self {
        MetaAcc { merged: meta, ports: vec![(port, meta)] }
    }

    fn note(&mut self, port: PortId, meta: MsgMeta) {
        self.merged = self.merged.merge(meta);
        match self.ports.iter_mut().find(|(p, _)| *p == port) {
            Some((_, m)) => *m = m.merge(meta),
            None => self.ports.push((port, meta)),
        }
    }

    fn absorb(&mut self, other: &MetaAcc) {
        for &(p, m) in &other.ports {
            self.note(p, m);
        }
        // ports may be empty for synthetic accs; keep merged authoritative
        self.merged = self.merged.merge(other.merged);
    }

    fn port_meta(&self, port: PortId) -> Option<MsgMeta> {
        self.ports.iter().find(|(p, _)| *p == port).map(|(_, m)| *m)
    }
}

/// Metadata recorded at forward-emission time, consumed by the matching
/// backward arrival.
#[derive(Clone, Debug)]
struct OutMeta {
    /// Upstream metadata (pre-stamp): what `emit_bwd` echoes.
    upstream: MetaAcc,
    /// The version tag the emitted forward message carried (post-stamp):
    /// the staleness reference for [`NodeCtx::fwd_version`].
    stamped: Option<u64>,
}

struct StashEntry {
    value: Box<dyn Any + Send>,
    meta: MetaAcc,
}

/// Runtime-owned per-node state: the typed per-instance stash and the
/// forward-output metadata ledger. Lives next to the node in its
/// [`super::graph::NodeSlot`] (sim engine) or worker host (threaded).
#[derive(Default)]
pub struct NodeRt {
    stash: HashMap<StateKey, StashEntry>,
    out_meta: HashMap<StateKey, OutMeta>,
}

impl NodeRt {
    pub fn new() -> Self {
        Self::default()
    }

    /// Keys currently cached for this node (uniform leak accounting:
    /// engines add this to the node's own `cached_keys()`).
    pub fn cached(&self) -> usize {
        self.stash.len() + self.out_meta.len()
    }
}

/// Per-invocation context handed to nodes: the worker's backend, the
/// event channel, and the runtime services (emission, stash, metadata).
/// (Parameters live *inside* PPT nodes — the paper's local update rule —
/// so no parameter server appears here.)
pub struct NodeCtx<'a> {
    pub backend: &'a mut dyn Backend,
    pub events: &'a dyn EventSink,
    pub node_id: NodeId,
    rt: &'a mut NodeRt,
    acc: MetaAcc,
    /// The node's own version stamp (`Node::version()` at invocation).
    self_version: Option<u64>,
    /// Backward only: the version this node's forward output carried,
    /// from the incoming echo or the runtime's ledger.
    fwd_version: Option<u64>,
    out: Vec<(PortId, Message)>,
}

impl<'a> NodeCtx<'a> {
    #[allow(clippy::too_many_arguments)]
    fn new(
        backend: &'a mut dyn Backend,
        events: &'a dyn EventSink,
        node_id: NodeId,
        rt: &'a mut NodeRt,
        dir: Dir,
        port: PortId,
        state: &MsgState,
        meta: MsgMeta,
        self_version: Option<u64>,
    ) -> Self {
        let (acc, fwd_version) = match dir {
            Dir::Fwd => (MetaAcc::from_port(port, meta), None),
            Dir::Bwd => match rt.out_meta.remove(&state.key()) {
                // The ledger hit: echo the upstream producers' tags and
                // recover the stamped version for staleness. The ledger
                // is authoritative — the incoming echo may have been
                // max-merged with a parallel branch's (larger) tag at a
                // downstream join, which would understate staleness.
                // Hop counts are the exception: the ledger recorded the
                // *forward-time* count, while the incoming backward
                // carries the full round trip so far — take the max so
                // the controller sees cumulative pipeline depth.
                Some(om) => {
                    let v = om.stamped.or(meta.param_version);
                    let mut acc = om.upstream;
                    acc.merged.hops = acc.merged.hops.max(meta.hops);
                    (acc, v)
                }
                // Untracked (repeat backward on a fan-out state whose
                // first arrival consumed the entry): pass the echo along.
                None => (MetaAcc::from_port(port, meta), meta.param_version),
            },
        };
        NodeCtx {
            backend,
            events,
            node_id,
            rt,
            acc,
            self_version,
            fwd_version,
            out: Vec::new(),
        }
    }

    /// Emit an out-of-band controller event.
    pub fn emit(&self, ev: Event) {
        self.events.send_event(ev);
    }

    /// Is this invocation training traffic? (Non-train lanes skip
    /// backward caches and backprop; the runtime merges the lane across
    /// joins by severity rank.)
    pub fn grad_enabled(&self) -> bool {
        self.acc.merged.lane == Lane::Train
    }

    /// The invocation's merged lane tag.
    pub fn lane(&self) -> Lane {
        self.acc.merged.lane
    }

    /// Is this invocation an online-serving request? Parameterized nodes
    /// read the CoW snapshot instead of the live parameters when serving
    /// (DESIGN.md §15).
    pub fn serving(&self) -> bool {
        self.acc.merged.lane == Lane::Infer
    }

    /// Backward invocations: the parameter-version tag this node's
    /// forward output carried (the staleness reference). `None` on
    /// untagged chains.
    pub fn fwd_version(&self) -> Option<u64> {
        self.fwd_version
    }

    /// Emit a forward message out of `port` with state `state`. The
    /// runtime attaches the invocation's merged metadata, stamps the
    /// node's own version if it is parameterized, bumps the hop count
    /// (merge rule: max over inputs, +1 on emit), and (train mode)
    /// records the echo ledger entry for the matching backward.
    pub fn emit_fwd(&mut self, port: PortId, state: MsgState, payload: Vec<Tensor>) {
        let mut meta = self.acc.merged;
        if let Some(v) = self.self_version {
            meta.param_version = Some(v);
        }
        meta.hops = self.acc.merged.hops.saturating_add(1);
        if meta.lane == Lane::Train {
            self.rt.out_meta.insert(
                state.key(),
                OutMeta { upstream: self.acc.clone(), stamped: meta.param_version },
            );
        }
        self.out.push((port, Message { dir: Dir::Fwd, state, payload, meta }));
    }

    /// Emit a backward message out of input port `port` with state
    /// `state`, echoing that port's original producer tag upstream (the
    /// merged tag when the port is not individually known). The hop
    /// count is cumulative, not the per-port echo: max over this
    /// invocation's inputs, +1.
    pub fn emit_bwd(&mut self, port: PortId, state: MsgState, payload: Vec<Tensor>) {
        let mut meta = self.acc.port_meta(port).unwrap_or(self.acc.merged);
        meta.hops = self.acc.merged.hops.saturating_add(1);
        self.out.push((port, Message { dir: Dir::Bwd, state, payload, meta }));
    }

    /// Park `value` under `key` in both train and eval mode (join
    /// buffers). The invocation's metadata-so-far is recorded with it and
    /// re-merged by the matching [`NodeCtx::take`]. Duplicate keys are an
    /// error: the §4 state invariant makes them a node bug.
    pub fn stash<T: Send + 'static>(&mut self, key: StateKey, value: T) -> Result<()> {
        ensure!(
            !self.rt.stash.contains_key(&key),
            "duplicate stash for {:?}",
            key
        );
        self.rt.stash.insert(key, StashEntry { value: Box::new(value), meta: self.acc.clone() });
        Ok(())
    }

    /// Like [`NodeCtx::stash`], but only in training mode — the uniform
    /// eval-mode skip for backward-pass caches. No-op (Ok) in eval.
    pub fn stash_bwd<T: Send + 'static>(&mut self, key: StateKey, value: T) -> Result<()> {
        if !self.grad_enabled() {
            return Ok(());
        }
        self.stash(key, value)
    }

    /// Remove and return the stashed value at `key`, merging the
    /// metadata recorded with it into this invocation's accumulator
    /// (this is how fwd→cache→bwd threading and join merging happen).
    ///
    /// An entry of a *different* type at `key` is left in place and
    /// `None` is returned: the caller then reports its own "missing
    /// record" error (or trips the duplicate-stash check), which the
    /// engines surface with node context — a cross-type key collision is
    /// a node bug and must not abort a worker thread.
    pub fn take<T: Send + 'static>(&mut self, key: StateKey) -> Option<T> {
        if !self
            .rt
            .stash
            .get(&key)
            .is_some_and(|e| e.value.downcast_ref::<T>().is_some())
        {
            return None;
        }
        let entry = self.rt.stash.remove(&key).expect("checked above");
        self.acc.absorb(&entry.meta);
        Some(*entry.value.downcast::<T>().expect("checked above"))
    }

    /// Key of the first stashed entry of type `T` matching `pred`
    /// (linear scan over in-flight keys — used by Ungroup/Flatmap whose
    /// backward must locate the parent entry a member belongs to).
    pub fn find_key<T: Send + 'static>(
        &self,
        pred: impl Fn(&StateKey, &T) -> bool,
    ) -> Option<StateKey> {
        self.rt
            .stash
            .iter()
            .find(|(k, e)| e.value.downcast_ref::<T>().is_some_and(|v| pred(k, v)))
            .map(|(k, _)| *k)
    }

    fn finish(self) -> Vec<(PortId, Message)> {
        self.out
    }
}

/// Drive one node invocation: decompose the message, run the node with a
/// runtime context, and return the routed outputs. The single
/// implementation of the invocation protocol, shared by both engines and
/// by node unit tests.
#[allow(clippy::too_many_arguments)]
pub fn invoke(
    node: &mut dyn Node,
    rt: &mut NodeRt,
    backend: &mut dyn Backend,
    events: &dyn EventSink,
    node_id: NodeId,
    dir: Dir,
    port: PortId,
    state: MsgState,
    payload: Vec<Tensor>,
    meta: MsgMeta,
) -> Result<Vec<(PortId, Message)>> {
    let self_version = node.version();
    let mut ctx = NodeCtx::new(backend, events, node_id, rt, dir, port, &state, meta, self_version);
    match dir {
        Dir::Fwd => node.forward(port, state, payload, &mut ctx)?,
        Dir::Bwd => node.backward(port, state, payload, &mut ctx)?,
    }
    Ok(ctx.finish())
}

/// Convenience for engines and tests: drive a whole [`Message`].
pub fn invoke_msg(
    node: &mut dyn Node,
    rt: &mut NodeRt,
    backend: &mut dyn Backend,
    events: &dyn EventSink,
    node_id: NodeId,
    port: PortId,
    msg: Message,
) -> Result<Vec<(PortId, Message)>> {
    let Message { dir, state, payload, meta } = msg;
    invoke(node, rt, backend, events, node_id, dir, port, state, payload, meta)
}

/// Run a node's end-of-epoch flush under a runtime context (flushes emit
/// events, never messages).
pub fn flush_node(
    node: &mut dyn Node,
    rt: &mut NodeRt,
    backend: &mut dyn Backend,
    events: &dyn EventSink,
    node_id: NodeId,
) -> Result<()> {
    let state = MsgState::default();
    let self_version = node.version();
    let mut ctx = NodeCtx::new(
        backend,
        events,
        node_id,
        rt,
        Dir::Fwd,
        0,
        &state,
        MsgMeta::train(),
        self_version,
    );
    node.flush(&mut ctx)?;
    let out = ctx.finish();
    if !out.is_empty() {
        return Err(anyhow!("node '{}' emitted {} messages during flush", node.name(), out.len()));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;
    use std::sync::mpsc::channel;

    /// Pass-through node used to probe the runtime's meta threading.
    struct Echo;
    impl Node for Echo {
        fn forward(
            &mut self,
            _port: PortId,
            state: MsgState,
            payload: Vec<Tensor>,
            ctx: &mut NodeCtx,
        ) -> Result<()> {
            ctx.emit_fwd(0, state, payload);
            Ok(())
        }
        fn backward(
            &mut self,
            _port: PortId,
            state: MsgState,
            payload: Vec<Tensor>,
            ctx: &mut NodeCtx,
        ) -> Result<()> {
            ctx.emit_bwd(0, state, payload);
            Ok(())
        }
        fn name(&self) -> &str {
            "echo"
        }
    }

    /// Stamping node: pretends to be parameterized at version 9.
    struct Stamp;
    impl Node for Stamp {
        fn forward(
            &mut self,
            _port: PortId,
            state: MsgState,
            payload: Vec<Tensor>,
            ctx: &mut NodeCtx,
        ) -> Result<()> {
            ctx.emit_fwd(0, state, payload);
            Ok(())
        }
        fn backward(
            &mut self,
            _port: PortId,
            state: MsgState,
            payload: Vec<Tensor>,
            ctx: &mut NodeCtx,
        ) -> Result<()> {
            ctx.emit_bwd(0, state, payload);
            Ok(())
        }
        fn version(&self) -> Option<u64> {
            Some(9)
        }
        fn name(&self) -> &str {
            "stamp"
        }
    }

    fn drive(
        node: &mut dyn Node,
        rt: &mut NodeRt,
        msg: Message,
    ) -> Vec<(PortId, Message)> {
        let (tx, _rx) = channel();
        let mut be = NativeBackend::new();
        invoke_msg(node, rt, &mut be, &tx, 0, 0, msg).unwrap()
    }

    #[test]
    fn passthrough_propagates_meta_and_ledger_echoes() {
        let mut n = Echo;
        let mut rt = NodeRt::new();
        let s = MsgState::for_instance(1);
        let out = drive(&mut n, &mut rt, Message::fwd(s, vec![]).versioned(5));
        assert_eq!(out[0].1.version(), Some(5), "non-parameterized: tag flows through");
        assert!(out[0].1.is_train());
        assert_eq!(rt.cached(), 1, "train fwd emission records the echo ledger");
        // backward with a *different* (corrupt) echo: ledger wins upstream
        let back = drive(&mut n, &mut rt, Message::bwd(s, vec![]).versioned(77));
        assert_eq!(back[0].1.version(), Some(5), "echo restores the upstream tag");
        assert_eq!(rt.cached(), 0, "ledger entry consumed — leak-free");
    }

    #[test]
    fn parameterized_node_stamps_its_own_version() {
        let mut n = Stamp;
        let mut rt = NodeRt::new();
        let s = MsgState::for_instance(2);
        let out = drive(&mut n, &mut rt, Message::fwd(s, vec![]).versioned(3));
        assert_eq!(out[0].1.version(), Some(9), "own stamp overrides upstream");
        // downstream echoes the stamp back; emit_bwd echoes upstream's 3
        let back = drive(&mut n, &mut rt, Message::bwd(s, vec![]).versioned(9));
        assert_eq!(back[0].1.version(), Some(3));
    }

    #[test]
    fn eval_mode_records_nothing() {
        let mut n = Echo;
        let mut rt = NodeRt::new();
        let s = MsgState::for_instance(3);
        let out = drive(&mut n, &mut rt, Message::eval(s, vec![]));
        assert!(!out[0].1.is_train());
        assert_eq!(rt.cached(), 0, "eval traffic must not populate the ledger");
    }

    #[test]
    fn stash_carries_meta_through_take() {
        struct Joiner;
        impl Node for Joiner {
            fn forward(
                &mut self,
                port: PortId,
                state: MsgState,
                payload: Vec<Tensor>,
                ctx: &mut NodeCtx,
            ) -> Result<()> {
                // 2-way join keyed on instance: first arrival stashes,
                // second takes and emits.
                let key = state.key();
                match ctx.take::<Vec<Tensor>>(key) {
                    Some(mut first) => {
                        first.extend(payload);
                        ctx.emit_fwd(0, state, first);
                    }
                    None => ctx.stash(key, payload)?,
                }
                let _ = port;
                Ok(())
            }
            fn backward(
                &mut self,
                _port: PortId,
                _state: MsgState,
                _payload: Vec<Tensor>,
                _ctx: &mut NodeCtx,
            ) -> Result<()> {
                unreachable!()
            }
            fn name(&self) -> &str {
                "joiner"
            }
        }
        let mut n = Joiner;
        let mut rt = NodeRt::new();
        let s = MsgState::for_instance(4);
        assert!(drive(&mut n, &mut rt, Message::fwd(s, vec![]).versioned(4)).is_empty());
        let out = drive(&mut n, &mut rt, Message::fwd(s, vec![]).versioned(2));
        assert_eq!(
            out[0].1.version(),
            Some(4),
            "join merges versions by max across stashed arrivals"
        );
        // eval on one side poisons train on the joined output
        let mut rt = NodeRt::new();
        let s = MsgState::for_instance(5);
        drive(&mut n, &mut rt, Message::fwd(s, vec![]));
        let out = drive(&mut n, &mut rt, Message::eval(s, vec![]));
        assert!(!out[0].1.is_train(), "train is AND-ed across join inputs");
    }

    #[test]
    fn hop_counts_increment_per_emission_and_accumulate_backward() {
        let mut a = Echo;
        let mut b = Echo;
        let (mut rt_a, mut rt_b) = (NodeRt::new(), NodeRt::new());
        let s = MsgState::for_instance(8);
        let out = drive(&mut a, &mut rt_a, Message::fwd(s, vec![]));
        assert_eq!(out[0].1.hops(), 1, "one emission from a hop-0 pump");
        let out2 = drive(&mut b, &mut rt_b, out[0].1.clone());
        assert_eq!(out2[0].1.hops(), 2, "chained emission increments");
        // downstream turned around at hop 3; the backward through b must
        // carry the cumulative round trip (max of ledger fwd-time count
        // and the incoming echo, +1), then through a again
        let mut bwd = Message::bwd(s, vec![]);
        bwd.meta.hops = 3;
        let back_b = drive(&mut b, &mut rt_b, bwd);
        assert_eq!(back_b[0].1.hops(), 4);
        let back_a = drive(&mut a, &mut rt_a, back_b[0].1.clone());
        assert_eq!(back_a[0].1.hops(), 5, "controller sees ~2x pipeline depth");
    }

    #[test]
    fn joins_take_the_longest_hop_path() {
        // reuse the stash-based joiner shape: two arrivals with different
        // hop counts merge by max before the +1 emission bump
        struct Join2;
        impl Node for Join2 {
            fn forward(
                &mut self,
                _port: PortId,
                state: MsgState,
                payload: Vec<Tensor>,
                ctx: &mut NodeCtx,
            ) -> Result<()> {
                let key = state.key();
                match ctx.take::<Vec<Tensor>>(key) {
                    Some(_) => ctx.emit_fwd(0, state, payload),
                    None => ctx.stash(key, payload)?,
                }
                Ok(())
            }
            fn backward(
                &mut self,
                _port: PortId,
                _state: MsgState,
                _payload: Vec<Tensor>,
                _ctx: &mut NodeCtx,
            ) -> Result<()> {
                unreachable!()
            }
            fn name(&self) -> &str {
                "join2"
            }
        }
        let mut n = Join2;
        let mut rt = NodeRt::new();
        let s = MsgState::for_instance(9);
        let mut short = Message::fwd(s, vec![]);
        short.meta.hops = 1;
        let mut long = Message::fwd(s, vec![]);
        long.meta.hops = 6;
        assert!(drive(&mut n, &mut rt, short).is_empty());
        let out = drive(&mut n, &mut rt, long);
        assert_eq!(out[0].1.hops(), 7, "max(1, 6) + 1");
    }

    #[test]
    fn duplicate_stash_is_rejected() {
        let mut rt = NodeRt::new();
        let (tx, _rx) = channel();
        let mut be = NativeBackend::new();
        let s = MsgState::for_instance(6);
        let mut ctx = NodeCtx::new(
            &mut be,
            &tx,
            0,
            &mut rt,
            Dir::Fwd,
            0,
            &s,
            MsgMeta::train(),
            None,
        );
        ctx.stash(s.key(), 1u32).unwrap();
        assert!(ctx.stash(s.key(), 2u32).is_err());
        assert_eq!(ctx.take::<u32>(s.key()), Some(1));
    }
}
