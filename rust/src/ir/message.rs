//! Messages: (payload, state, direction) triples flowing through the IR,
//! plus the [`MsgMeta`] sidecar the node runtime threads through the
//! graph automatically.

use crate::tensor::Tensor;

use super::state::MsgState;

/// Direction of travel. Backward messages carry cotangents and are
/// prioritized by workers (Appendix A).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    Fwd,
    Bwd,
}

impl Dir {
    /// Single-byte encoding for the transport wire format.
    pub fn to_wire(self) -> u8 {
        match self {
            Dir::Fwd => 0,
            Dir::Bwd => 1,
        }
    }

    /// Inverse of [`Dir::to_wire`]; `None` for unknown bytes so the wire
    /// decoder can reject corrupt frames instead of guessing.
    pub fn from_wire(b: u8) -> Option<Dir> {
        match b {
            0 => Some(Dir::Fwd),
            1 => Some(Dir::Bwd),
            _ => None,
        }
    }
}

/// Cross-cutting message metadata, owned and propagated by the node
/// runtime ([`crate::ir::rt`]) — node implementations never read or
/// write it directly.
///
/// * `train = false` marks evaluation traffic: the runtime skips every
///   backward-pass cache and the loss layer reports metrics instead of
///   starting backprop.
/// * `param_version` is the control plane's staleness wire protocol
///   (DESIGN.md §9–§10): a parameterized node stamps its forward outputs
///   with its monotone update counter, the runtime caches the tag
///   alongside the activation, and backward cotangents echo it — so the
///   backward message arriving at a node carries *that node's* parameter
///   version at forward time, and the version delta
///   `updates_now - param_version` is the gradient staleness the
///   optimizer's staleness policy acts on. `None` marks untagged traffic
///   (pumped inputs before the first parameterized producer).
/// * `hops` counts runtime emissions along the message's longest causal
///   path: pumped inputs start at 0, every `emit_fwd`/`emit_bwd` stamps
///   `max(inputs) + 1`, and joins take the max. A backward message
///   reaching the controller therefore carries (roughly) twice the
///   pipeline depth its instance traversed — a model-free depth estimate
///   for admission policies (`ControlObs::hop_depth`).
///
/// Future tags (deadlines, priorities) belong here; the merge rule below
/// is the single place multi-input joins combine them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MsgMeta {
    pub train: bool,
    pub param_version: Option<u64>,
    /// Emission count along the longest causal path (merge: max, then
    /// +1 at each runtime emission).
    pub hops: u32,
}

impl MsgMeta {
    /// Untagged training-mode metadata (pumped inputs).
    pub fn train() -> Self {
        MsgMeta { train: true, param_version: None, hops: 0 }
    }

    /// Untagged evaluation-mode metadata.
    pub fn eval() -> Self {
        MsgMeta { train: false, param_version: None, hops: 0 }
    }

    pub fn for_mode(train: bool) -> Self {
        MsgMeta { train, param_version: None, hops: 0 }
    }

    /// The multi-input join rule (ISSUE 4 / DESIGN.md §10): `train` is
    /// AND-ed (one eval input makes the join eval), versions take the
    /// element-wise max (a conservative upper bound when branches carry
    /// different producers' counters; exact when they agree), hop counts
    /// take the max (longest causal path wins; the +1 happens at
    /// emission, not here).
    pub fn merge(self, other: MsgMeta) -> MsgMeta {
        MsgMeta {
            train: self.train && other.train,
            param_version: match (self.param_version, other.param_version) {
                (Some(a), Some(b)) => Some(a.max(b)),
                (a, b) => a.or(b),
            },
            hops: self.hops.max(other.hops),
        }
    }
}

impl Default for MsgMeta {
    fn default() -> Self {
        MsgMeta::train()
    }
}

/// A message. `payload` usually holds one tensor; recurrent cells carry
/// two (h, c). The metadata sidecar travels in `meta` and is managed by
/// the node runtime, not by node implementations.
///
/// `Message::clone` is cheap: tensors are Arc-backed copy-on-write, so
/// cloning for fan-out, replay buffers or activation caches bumps
/// refcounts instead of copying payload data (DESIGN.md §8).
#[derive(Clone, Debug)]
pub struct Message {
    pub dir: Dir,
    pub state: MsgState,
    pub payload: Vec<Tensor>,
    pub meta: MsgMeta,
}

impl Message {
    pub fn fwd(state: MsgState, payload: Vec<Tensor>) -> Self {
        Message { dir: Dir::Fwd, state, payload, meta: MsgMeta::train() }
    }

    pub fn bwd(state: MsgState, payload: Vec<Tensor>) -> Self {
        Message { dir: Dir::Bwd, state, payload, meta: MsgMeta::train() }
    }

    pub fn eval(state: MsgState, payload: Vec<Tensor>) -> Self {
        Message { dir: Dir::Fwd, state, payload, meta: MsgMeta::eval() }
    }

    /// Tag with the producing node's parameter version (builder-style).
    pub fn versioned(mut self, version: u64) -> Self {
        self.meta.param_version = Some(version);
        self
    }

    /// Evaluation traffic? (convenience over `meta.train`)
    pub fn is_train(&self) -> bool {
        self.meta.train
    }

    /// The version tag (convenience over `meta.param_version`).
    pub fn version(&self) -> Option<u64> {
        self.meta.param_version
    }

    /// The hop-count tag (convenience over `meta.hops`).
    pub fn hops(&self) -> u32 {
        self.meta.hops
    }

    /// Single-tensor convenience accessor.
    pub fn tensor(&self) -> &Tensor {
        assert_eq!(self.payload.len(), 1, "message has {} payload tensors", self.payload.len());
        &self.payload[0]
    }

    /// Approximate wire size in bytes (payload only), for the FPGA
    /// bandwidth model and metrics.
    pub fn wire_bytes(&self) -> usize {
        self.payload.iter().map(|t| t.len() * 4).sum::<usize>()
            + std::mem::size_of::<MsgState>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_set_direction_and_mode() {
        let s = MsgState::for_instance(7);
        let m = Message::fwd(s, vec![Tensor::scalar(1.0)]);
        assert_eq!(m.dir, Dir::Fwd);
        assert!(m.is_train());
        assert_eq!(m.version(), None, "pumped traffic is untagged");
        let b = Message::bwd(s, vec![]);
        assert_eq!(b.dir, Dir::Bwd);
        let e = Message::eval(s, vec![]);
        assert!(!e.is_train());
    }

    #[test]
    fn versioned_tags_the_message() {
        let s = MsgState::for_instance(3);
        let m = Message::fwd(s, vec![]).versioned(42);
        assert_eq!(m.version(), Some(42));
        assert_eq!(m.clone().version(), Some(42), "tag survives clone");
    }

    #[test]
    fn merge_ands_train_and_maxes_versions() {
        let a = MsgMeta { train: true, param_version: Some(3), hops: 2 };
        let b = MsgMeta { train: true, param_version: Some(7), hops: 5 };
        let c = MsgMeta { train: false, param_version: None, hops: 0 };
        assert_eq!(a.merge(b).param_version, Some(7));
        assert!(a.merge(b).train);
        assert_eq!(a.merge(b).hops, 5, "longest causal path wins");
        let m = a.merge(c);
        assert!(!m.train, "one eval input makes the join eval");
        assert_eq!(m.param_version, Some(3), "None is absent, not zero");
        assert_eq!(m.hops, 2);
        assert_eq!(MsgMeta::train().merge(MsgMeta::train()).param_version, None);
    }

    #[test]
    fn constructors_start_at_zero_hops() {
        let s = MsgState::for_instance(9);
        assert_eq!(MsgMeta::train().hops, 0);
        assert_eq!(MsgMeta::eval().hops, 0);
        assert_eq!(Message::fwd(s, vec![]).hops(), 0, "pumped traffic is hop 0");
    }

    #[test]
    fn dir_wire_roundtrip_rejects_unknown_bytes() {
        for d in [Dir::Fwd, Dir::Bwd] {
            assert_eq!(Dir::from_wire(d.to_wire()), Some(d));
        }
        assert_eq!(Dir::from_wire(2), None);
        assert_eq!(Dir::from_wire(255), None);
    }

    #[test]
    fn clone_shares_payload_storage() {
        // the zero-copy hot path: cloning a message must not copy tensors
        let s = MsgState::for_instance(2);
        let m = Message::fwd(s, vec![Tensor::zeros(&[8, 8])]);
        let c = m.clone();
        assert!(m.payload[0].shares_storage(&c.payload[0]));
    }

    #[test]
    fn wire_bytes_counts_payload_and_state() {
        let s = MsgState::for_instance(1);
        let m = Message::fwd(s, vec![Tensor::zeros(&[2, 3])]);
        assert_eq!(m.wire_bytes(), 24 + std::mem::size_of::<MsgState>());
    }
}
