//! Messages: (payload, state, direction) triples flowing through the IR,
//! tagged with the parameter version they were computed against.

use crate::tensor::Tensor;

use super::state::MsgState;

/// Direction of travel. Backward messages carry cotangents and are
/// prioritized by workers (Appendix A).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    Fwd,
    Bwd,
}

/// A message. `payload` usually holds one tensor; recurrent cells carry
/// two (h, c). `train=false` marks evaluation traffic: nodes skip caching
/// and the loss layer reports metrics instead of starting backprop.
///
/// `param_version` is the control plane's staleness wire protocol
/// (DESIGN.md §9): a parameterized node tags its forward outputs with its
/// monotone update counter, consumers cache the tag alongside the
/// activation, and backward cotangents echo it — so the backward message
/// arriving at a node carries *that node's* parameter version at forward
/// time, and the version delta `updates_now - param_version` is the
/// gradient staleness the optimizer's staleness policy acts on. `None`
/// marks untagged traffic (pumped inputs, non-parameterized producers).
///
/// `Message::clone` is cheap: tensors are Arc-backed copy-on-write, so
/// cloning for fan-out, replay buffers or activation caches bumps
/// refcounts instead of copying payload data (DESIGN.md §8).
#[derive(Clone, Debug)]
pub struct Message {
    pub dir: Dir,
    pub state: MsgState,
    pub payload: Vec<Tensor>,
    pub train: bool,
    pub param_version: Option<u64>,
}

impl Message {
    pub fn fwd(state: MsgState, payload: Vec<Tensor>) -> Self {
        Message { dir: Dir::Fwd, state, payload, train: true, param_version: None }
    }

    pub fn bwd(state: MsgState, payload: Vec<Tensor>) -> Self {
        Message { dir: Dir::Bwd, state, payload, train: true, param_version: None }
    }

    pub fn eval(state: MsgState, payload: Vec<Tensor>) -> Self {
        Message { dir: Dir::Fwd, state, payload, train: false, param_version: None }
    }

    /// Tag with the producing node's parameter version (builder-style).
    pub fn versioned(mut self, version: u64) -> Self {
        self.param_version = Some(version);
        self
    }

    /// Single-tensor convenience accessor.
    pub fn tensor(&self) -> &Tensor {
        assert_eq!(self.payload.len(), 1, "message has {} payload tensors", self.payload.len());
        &self.payload[0]
    }

    /// Approximate wire size in bytes (payload only), for the FPGA
    /// bandwidth model and metrics.
    pub fn wire_bytes(&self) -> usize {
        self.payload.iter().map(|t| t.len() * 4).sum::<usize>()
            + std::mem::size_of::<MsgState>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_set_direction_and_mode() {
        let s = MsgState::for_instance(7);
        let m = Message::fwd(s, vec![Tensor::scalar(1.0)]);
        assert_eq!(m.dir, Dir::Fwd);
        assert!(m.train);
        assert_eq!(m.param_version, None, "pumped traffic is untagged");
        let b = Message::bwd(s, vec![]);
        assert_eq!(b.dir, Dir::Bwd);
        let e = Message::eval(s, vec![]);
        assert!(!e.train);
    }

    #[test]
    fn versioned_tags_the_message() {
        let s = MsgState::for_instance(3);
        let m = Message::fwd(s, vec![]).versioned(42);
        assert_eq!(m.param_version, Some(42));
        assert_eq!(m.clone().param_version, Some(42), "tag survives clone");
    }

    #[test]
    fn clone_shares_payload_storage() {
        // the zero-copy hot path: cloning a message must not copy tensors
        let s = MsgState::for_instance(2);
        let m = Message::fwd(s, vec![Tensor::zeros(&[8, 8])]);
        let c = m.clone();
        assert!(m.payload[0].shares_storage(&c.payload[0]));
    }

    #[test]
    fn wire_bytes_counts_payload_and_state() {
        let s = MsgState::for_instance(1);
        let m = Message::fwd(s, vec![Tensor::zeros(&[2, 3])]);
        assert_eq!(m.wire_bytes(), 24 + std::mem::size_of::<MsgState>());
    }
}
