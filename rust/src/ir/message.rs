//! Messages: (payload, state, direction) triples flowing through the IR.

use crate::tensor::Tensor;

use super::state::MsgState;

/// Direction of travel. Backward messages carry cotangents and are
/// prioritized by workers (Appendix A).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    Fwd,
    Bwd,
}

/// A message. `payload` usually holds one tensor; recurrent cells carry
/// two (h, c). `train=false` marks evaluation traffic: nodes skip caching
/// and the loss layer reports metrics instead of starting backprop.
///
/// `Message::clone` is cheap: tensors are Arc-backed copy-on-write, so
/// cloning for fan-out, replay buffers or activation caches bumps
/// refcounts instead of copying payload data (DESIGN.md §8).
#[derive(Clone, Debug)]
pub struct Message {
    pub dir: Dir,
    pub state: MsgState,
    pub payload: Vec<Tensor>,
    pub train: bool,
}

impl Message {
    pub fn fwd(state: MsgState, payload: Vec<Tensor>) -> Self {
        Message { dir: Dir::Fwd, state, payload, train: true }
    }

    pub fn bwd(state: MsgState, payload: Vec<Tensor>) -> Self {
        Message { dir: Dir::Bwd, state, payload, train: true }
    }

    pub fn eval(state: MsgState, payload: Vec<Tensor>) -> Self {
        Message { dir: Dir::Fwd, state, payload, train: false }
    }

    /// Single-tensor convenience accessor.
    pub fn tensor(&self) -> &Tensor {
        assert_eq!(self.payload.len(), 1, "message has {} payload tensors", self.payload.len());
        &self.payload[0]
    }

    /// Approximate wire size in bytes (payload only), for the FPGA
    /// bandwidth model and metrics.
    pub fn wire_bytes(&self) -> usize {
        self.payload.iter().map(|t| t.len() * 4).sum::<usize>()
            + std::mem::size_of::<MsgState>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_set_direction_and_mode() {
        let s = MsgState::for_instance(7);
        let m = Message::fwd(s, vec![Tensor::scalar(1.0)]);
        assert_eq!(m.dir, Dir::Fwd);
        assert!(m.train);
        let b = Message::bwd(s, vec![]);
        assert_eq!(b.dir, Dir::Bwd);
        let e = Message::eval(s, vec![]);
        assert!(!e.train);
    }

    #[test]
    fn clone_shares_payload_storage() {
        // the zero-copy hot path: cloning a message must not copy tensors
        let s = MsgState::for_instance(2);
        let m = Message::fwd(s, vec![Tensor::zeros(&[8, 8])]);
        let c = m.clone();
        assert!(m.payload[0].shares_storage(&c.payload[0]));
    }

    #[test]
    fn wire_bytes_counts_payload_and_state() {
        let s = MsgState::for_instance(1);
        let m = Message::fwd(s, vec![Tensor::zeros(&[2, 3])]);
        assert_eq!(m.wire_bytes(), 24 + std::mem::size_of::<MsgState>());
    }
}
