//! Messages: (payload, state, direction) triples flowing through the IR,
//! plus the [`MsgMeta`] sidecar the node runtime threads through the
//! graph automatically.

use crate::tensor::Tensor;

use super::state::MsgState;

/// Direction of travel. Backward messages carry cotangents and are
/// prioritized by workers (Appendix A).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    Fwd,
    Bwd,
}

impl Dir {
    /// Single-byte encoding for the transport wire format.
    pub fn to_wire(self) -> u8 {
        match self {
            Dir::Fwd => 0,
            Dir::Bwd => 1,
        }
    }

    /// Inverse of [`Dir::to_wire`]; `None` for unknown bytes so the wire
    /// decoder can reject corrupt frames instead of guessing.
    pub fn from_wire(b: u8) -> Option<Dir> {
        match b {
            0 => Some(Dir::Fwd),
            1 => Some(Dir::Bwd),
            _ => None,
        }
    }
}

/// Traffic lane: which stream class a message (and its instance) belongs
/// to. Lanes generalize the original train/eval mode bit into N
/// first-class stream classes (DESIGN.md §11/§15): per-lane watermarks,
/// per-lane admission quotas, per-lane occupancy accounting.
///
/// * `Train` — gradient-producing traffic; the only lane that mutates
///   parameters or optimizer state.
/// * `Eval` — forward-only validation traffic riding the live stream.
/// * `Infer` — forward-only online serving requests (`rust/src/serve`):
///   forwards read the CoW parameter *snapshot*, responses retire via
///   [`super::Event::InferDone`].
///
/// The ordering is a severity rank for the multi-input merge rule: a
/// join of mixed-lane inputs takes the most-restrictive (highest-rank)
/// lane, which reproduces the old "one eval input makes the join eval"
/// AND-rule and extends it to inference.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Lane {
    #[default]
    Train,
    Eval,
    Infer,
}

impl Lane {
    /// Number of lanes (sizes the per-lane accounting arrays).
    pub const COUNT: usize = 3;

    /// Every lane, in `idx` order.
    pub const ALL: [Lane; Lane::COUNT] = [Lane::Train, Lane::Eval, Lane::Infer];

    /// Dense index for per-lane arrays (`[T; Lane::COUNT]`).
    pub fn idx(self) -> usize {
        match self {
            Lane::Train => 0,
            Lane::Eval => 1,
            Lane::Infer => 2,
        }
    }

    /// Single-byte encoding for the transport wire format.
    pub fn to_wire(self) -> u8 {
        self.idx() as u8
    }

    /// Inverse of [`Lane::to_wire`]; `None` for unknown bytes.
    pub fn from_wire(b: u8) -> Option<Lane> {
        Lane::ALL.get(b as usize).copied()
    }

    /// Merge rule for multi-input joins: the most-restrictive lane wins
    /// (Train < Eval < Infer). With two lanes this is exactly the old
    /// `train && train` AND-rule.
    pub fn merge(self, other: Lane) -> Lane {
        if self.idx() >= other.idx() {
            self
        } else {
            other
        }
    }
}

impl std::fmt::Display for Lane {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Lane::Train => "train",
            Lane::Eval => "eval",
            Lane::Infer => "infer",
        };
        write!(f, "{s}")
    }
}

/// Cross-cutting message metadata, owned and propagated by the node
/// runtime ([`crate::ir::rt`]) — node implementations never read or
/// write it directly.
///
/// * `lane` marks the stream class. Non-`Train` lanes are forward-only:
///   the runtime skips every backward-pass cache and the loss layer
///   reports metrics (eval) or emits the response (infer) instead of
///   starting backprop.
/// * `param_version` is the control plane's staleness wire protocol
///   (DESIGN.md §9–§10): a parameterized node stamps its forward outputs
///   with its monotone update counter, the runtime caches the tag
///   alongside the activation, and backward cotangents echo it — so the
///   backward message arriving at a node carries *that node's* parameter
///   version at forward time, and the version delta
///   `updates_now - param_version` is the gradient staleness the
///   optimizer's staleness policy acts on. `None` marks untagged traffic
///   (pumped inputs before the first parameterized producer).
/// * `hops` counts runtime emissions along the message's longest causal
///   path: pumped inputs start at 0, every `emit_fwd`/`emit_bwd` stamps
///   `max(inputs) + 1`, and joins take the max. A backward message
///   reaching the controller therefore carries (roughly) twice the
///   pipeline depth its instance traversed — a model-free depth estimate
///   for admission policies (`ControlObs::hop_depth`).
/// * `deadline_us` is the serving SLO tag: the request's latency budget
///   in microseconds from admission. 0 means "no deadline" (all
///   train/eval traffic). The admission layer sheds requests whose
///   remaining budget can't cover the expected hop-depth latency
///   (DESIGN.md §15); the tag itself just rides the message so future
///   in-flight shedding can act on it.
///
/// The merge rule below is the single place multi-input joins combine
/// these tags.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MsgMeta {
    pub lane: Lane,
    pub param_version: Option<u64>,
    /// Emission count along the longest causal path (merge: max, then
    /// +1 at each runtime emission).
    pub hops: u32,
    /// Latency budget in µs from admission; 0 = no deadline.
    pub deadline_us: u32,
}

impl MsgMeta {
    /// Untagged metadata for a lane (pumped inputs).
    pub fn for_lane(lane: Lane) -> Self {
        MsgMeta { lane, param_version: None, hops: 0, deadline_us: 0 }
    }

    /// Untagged training-mode metadata (pumped inputs).
    pub fn train() -> Self {
        MsgMeta::for_lane(Lane::Train)
    }

    /// Untagged evaluation-mode metadata.
    pub fn eval() -> Self {
        MsgMeta::for_lane(Lane::Eval)
    }

    /// Untagged inference metadata carrying a deadline tag.
    pub fn infer(deadline_us: u32) -> Self {
        MsgMeta { deadline_us, ..MsgMeta::for_lane(Lane::Infer) }
    }

    /// Two-lane compatibility constructor (true = train, false = eval).
    pub fn for_mode(train: bool) -> Self {
        MsgMeta::for_lane(if train { Lane::Train } else { Lane::Eval })
    }

    /// Training-lane traffic? (convenience over `lane`)
    pub fn is_train(&self) -> bool {
        self.lane == Lane::Train
    }

    /// The multi-input join rule (ISSUE 4 / DESIGN.md §10): lanes take
    /// the most-restrictive rank (one eval input makes the join eval;
    /// one infer input makes it infer), versions take the element-wise
    /// max (a conservative upper bound when branches carry different
    /// producers' counters; exact when they agree), hop counts take the
    /// max (longest causal path wins; the +1 happens at emission, not
    /// here), and deadlines take the tightest non-zero budget.
    pub fn merge(self, other: MsgMeta) -> MsgMeta {
        MsgMeta {
            lane: self.lane.merge(other.lane),
            param_version: match (self.param_version, other.param_version) {
                (Some(a), Some(b)) => Some(a.max(b)),
                (a, b) => a.or(b),
            },
            hops: self.hops.max(other.hops),
            deadline_us: match (self.deadline_us, other.deadline_us) {
                (0, b) => b,
                (a, 0) => a,
                (a, b) => a.min(b),
            },
        }
    }
}

impl Default for MsgMeta {
    fn default() -> Self {
        MsgMeta::train()
    }
}

/// A message. `payload` usually holds one tensor; recurrent cells carry
/// two (h, c). The metadata sidecar travels in `meta` and is managed by
/// the node runtime, not by node implementations.
///
/// `Message::clone` is cheap: tensors are Arc-backed copy-on-write, so
/// cloning for fan-out, replay buffers or activation caches bumps
/// refcounts instead of copying payload data (DESIGN.md §8).
#[derive(Clone, Debug)]
pub struct Message {
    pub dir: Dir,
    pub state: MsgState,
    pub payload: Vec<Tensor>,
    pub meta: MsgMeta,
}

impl Message {
    pub fn fwd(state: MsgState, payload: Vec<Tensor>) -> Self {
        Message { dir: Dir::Fwd, state, payload, meta: MsgMeta::train() }
    }

    pub fn bwd(state: MsgState, payload: Vec<Tensor>) -> Self {
        Message { dir: Dir::Bwd, state, payload, meta: MsgMeta::train() }
    }

    pub fn eval(state: MsgState, payload: Vec<Tensor>) -> Self {
        Message { dir: Dir::Fwd, state, payload, meta: MsgMeta::eval() }
    }

    /// Tag with the producing node's parameter version (builder-style).
    pub fn versioned(mut self, version: u64) -> Self {
        self.meta.param_version = Some(version);
        self
    }

    /// Training-lane traffic? (convenience over `meta.lane`)
    pub fn is_train(&self) -> bool {
        self.meta.is_train()
    }

    /// The lane tag (convenience over `meta.lane`).
    pub fn lane(&self) -> Lane {
        self.meta.lane
    }

    /// The version tag (convenience over `meta.param_version`).
    pub fn version(&self) -> Option<u64> {
        self.meta.param_version
    }

    /// The hop-count tag (convenience over `meta.hops`).
    pub fn hops(&self) -> u32 {
        self.meta.hops
    }

    /// Single-tensor convenience accessor.
    pub fn tensor(&self) -> &Tensor {
        assert_eq!(self.payload.len(), 1, "message has {} payload tensors", self.payload.len());
        &self.payload[0]
    }

    /// Approximate wire size in bytes (payload only), for the FPGA
    /// bandwidth model and metrics.
    pub fn wire_bytes(&self) -> usize {
        self.payload.iter().map(|t| t.len() * 4).sum::<usize>()
            + std::mem::size_of::<MsgState>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_set_direction_and_mode() {
        let s = MsgState::for_instance(7);
        let m = Message::fwd(s, vec![Tensor::scalar(1.0)]);
        assert_eq!(m.dir, Dir::Fwd);
        assert!(m.is_train());
        assert_eq!(m.lane(), Lane::Train);
        assert_eq!(m.version(), None, "pumped traffic is untagged");
        let b = Message::bwd(s, vec![]);
        assert_eq!(b.dir, Dir::Bwd);
        let e = Message::eval(s, vec![]);
        assert!(!e.is_train());
        assert_eq!(e.lane(), Lane::Eval);
    }

    #[test]
    fn versioned_tags_the_message() {
        let s = MsgState::for_instance(3);
        let m = Message::fwd(s, vec![]).versioned(42);
        assert_eq!(m.version(), Some(42));
        assert_eq!(m.clone().version(), Some(42), "tag survives clone");
    }

    #[test]
    fn merge_ranks_lanes_and_maxes_versions() {
        let a = MsgMeta { param_version: Some(3), hops: 2, ..MsgMeta::train() };
        let b = MsgMeta { param_version: Some(7), hops: 5, ..MsgMeta::train() };
        let c = MsgMeta::eval();
        assert_eq!(a.merge(b).param_version, Some(7));
        assert_eq!(a.merge(b).lane, Lane::Train);
        assert_eq!(a.merge(b).hops, 5, "longest causal path wins");
        let m = a.merge(c);
        assert_eq!(m.lane, Lane::Eval, "one eval input makes the join eval");
        assert_eq!(m.param_version, Some(3), "None is absent, not zero");
        assert_eq!(m.hops, 2);
        assert_eq!(MsgMeta::train().merge(MsgMeta::train()).param_version, None);
        // infer outranks both
        assert_eq!(MsgMeta::eval().merge(MsgMeta::infer(0)).lane, Lane::Infer);
        assert_eq!(MsgMeta::train().merge(MsgMeta::infer(0)).lane, Lane::Infer);
    }

    #[test]
    fn merge_takes_tightest_nonzero_deadline() {
        let none = MsgMeta::infer(0);
        let tight = MsgMeta::infer(500);
        let loose = MsgMeta::infer(9000);
        assert_eq!(tight.merge(loose).deadline_us, 500);
        assert_eq!(loose.merge(tight).deadline_us, 500);
        assert_eq!(none.merge(loose).deadline_us, 9000, "0 means no deadline, not tightest");
        assert_eq!(loose.merge(none).deadline_us, 9000);
        assert_eq!(none.merge(none).deadline_us, 0);
    }

    #[test]
    fn lane_wire_roundtrip_and_indexing() {
        for (i, lane) in Lane::ALL.into_iter().enumerate() {
            assert_eq!(lane.idx(), i);
            assert_eq!(Lane::from_wire(lane.to_wire()), Some(lane));
        }
        assert_eq!(Lane::from_wire(Lane::COUNT as u8), None);
        assert_eq!(Lane::default(), Lane::Train);
        assert_eq!(Lane::Infer.to_string(), "infer");
    }

    #[test]
    fn constructors_start_at_zero_hops() {
        let s = MsgState::for_instance(9);
        assert_eq!(MsgMeta::train().hops, 0);
        assert_eq!(MsgMeta::eval().hops, 0);
        assert_eq!(MsgMeta::infer(100).hops, 0);
        assert_eq!(Message::fwd(s, vec![]).hops(), 0, "pumped traffic is hop 0");
    }

    #[test]
    fn dir_wire_roundtrip_rejects_unknown_bytes() {
        for d in [Dir::Fwd, Dir::Bwd] {
            assert_eq!(Dir::from_wire(d.to_wire()), Some(d));
        }
        assert_eq!(Dir::from_wire(2), None);
        assert_eq!(Dir::from_wire(255), None);
    }

    #[test]
    fn clone_shares_payload_storage() {
        // the zero-copy hot path: cloning a message must not copy tensors
        let s = MsgState::for_instance(2);
        let m = Message::fwd(s, vec![Tensor::zeros(&[8, 8])]);
        let c = m.clone();
        assert!(m.payload[0].shares_storage(&c.payload[0]));
    }

    #[test]
    fn wire_bytes_counts_payload_and_state() {
        let s = MsgState::for_instance(1);
        let m = Message::fwd(s, vec![Tensor::zeros(&[2, 3])]);
        assert_eq!(m.wire_bytes(), 24 + std::mem::size_of::<MsgState>());
    }
}
