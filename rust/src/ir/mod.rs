//! The static intermediate representation (IR) for dynamic control flow
//! (paper §4): message/state types, the graph, the node runtime, and the
//! node zoo.

pub mod build;
pub mod graph;
pub mod message;
pub mod nodes;
pub mod rt;
pub mod state;
pub mod viz;

pub use build::{
    CostAware, ExplicitPlacement, InPort, Net, NetBuilder, NodeHandle, NodeSpec, OutPort, Pinned,
    Placement, PlacementKind, RoundRobin,
};
pub use graph::{
    Endpoint, Event, EventSink, Graph, Node, NodeId, PortId, PumpSet, Route, WorkerId,
};
pub use message::{Dir, Lane, Message, MsgMeta};
pub use rt::{flush_node, invoke, invoke_msg, NodeCtx, NodeRt};
pub use state::{MsgState, StateKey};
