//! The static intermediate representation (IR) for dynamic control flow
//! (paper §4): message/state types, the graph, and the node zoo.

pub mod build;
pub mod graph;
pub mod message;
pub mod nodes;
pub mod state;
pub mod viz;

pub use build::{
    CostAware, InPort, Net, NetBuilder, NodeHandle, NodeSpec, OutPort, Pinned, Placement,
    PlacementKind, RoundRobin,
};
pub use graph::{
    pump_msg, Endpoint, Event, EventSink, Graph, Node, NodeCtx, NodeId, PortId, PumpSet, Route,
    WorkerId,
};
pub use message::{Dir, Message};
pub use state::{MsgState, StateKey};
