//! The static intermediate representation (IR) for dynamic control flow
//! (paper §4): message/state types, the graph, and the node zoo.

pub mod graph;
pub mod message;
pub mod nodes;
pub mod state;
pub mod viz;

pub use graph::{
    pump_msg, Endpoint, Event, EventSink, Graph, GraphBuilder, Node, NodeCtx, NodeId, PortId,
    PumpSet, Route, WorkerId,
};
pub use message::{Dir, Message};
pub use state::{MsgState, StateKey};
