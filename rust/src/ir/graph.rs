//! The static IR graph: nodes, typed ports, and the routing tables both
//! execution engines (threaded and simulated) share.

use std::sync::mpsc::Sender;

use anyhow::Result;

use crate::tensor::Tensor;

use super::message::{Dir, Lane, Message, MsgMeta};
use super::rt::{NodeCtx, NodeRt};
use super::state::MsgState;

pub type NodeId = usize;
pub type PortId = usize;
pub type WorkerId = usize;

/// Where a message is headed next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Endpoint {
    /// (node, port). For `Dir::Fwd` the port is the target's *input* port;
    /// for `Dir::Bwd` it is the target's *output* port the cotangent
    /// corresponds to.
    Node(NodeId, PortId),
    /// Back to the controller (graph boundary). Forward messages never
    /// route here; backward messages arriving here retire pumped inputs.
    Controller,
}

/// A routed message produced by a node.
#[derive(Debug)]
pub struct Route {
    pub to: Endpoint,
    pub msg: Message,
}

/// Events emitted by nodes toward the controller (out-of-band of the
/// message graph; in a distributed deployment these are the telemetry
/// channel back to the leader).
#[derive(Clone, Debug)]
pub enum Event {
    /// Loss layer processed one (prediction, label) pair.
    Loss {
        instance: u64,
        loss: f32,
        /// #correct and #examples for classification; (0, n) for regression.
        correct: u32,
        count: u32,
        /// Sum of absolute errors (regression only; 0 for classification).
        abs_err: f32,
        train: bool,
    },
    /// A parameterized node applied an accumulated update. `staleness`
    /// carries the drained applied-staleness counters *and* the bucketed
    /// histogram since the previous update event — the controller's
    /// per-edge staleness observability (DESIGN.md §10).
    Update {
        node: NodeId,
        staleness: crate::optim::StalenessStats,
    },
    /// Eval-mode instance finished at the loss layer.
    EvalDone { instance: u64 },
    /// Inference-lane instance finished at the loss layer; `output` is
    /// the model's prediction (Arc-backed clone — refcount bump, no
    /// copy), routed to the serving front-end as the response payload
    /// (DESIGN.md §15).
    InferDone { instance: u64, output: Vec<Tensor> },
}

impl Event {
    /// Build an [`Event::Update`] from a node's drained applied-staleness
    /// counters (see [`crate::optim::ParamSet::take_staleness_stats`]).
    pub fn update(node: NodeId, st: crate::optim::StalenessStats) -> Self {
        Event::Update { node, staleness: st }
    }
}

/// Where node events go. Implemented for plain mpsc senders (sim engine,
/// unit tests) and for the threaded engine's merged controller channel.
pub trait EventSink {
    fn send_event(&self, ev: Event);
}

impl EventSink for Sender<Event> {
    fn send_event(&self, ev: Event) {
        // The controller may have hung up after training; ignore.
        let _ = self.send(ev);
    }
}

/// An IR node: a state machine processing forward/backward messages.
/// `port` identifies which input (fwd) or output (bwd) the message
/// arrived on; outputs are emitted through the [`NodeCtx`], which owns
/// the cross-cutting concerns (metadata propagation, per-instance
/// caching, eval-mode skip) so implementations are pure compute — see
/// [`crate::ir::rt`].
pub trait Node: Send {
    fn forward(
        &mut self,
        port: PortId,
        state: MsgState,
        payload: Vec<Tensor>,
        ctx: &mut NodeCtx,
    ) -> Result<()>;

    fn backward(
        &mut self,
        port: PortId,
        state: MsgState,
        payload: Vec<Tensor>,
        ctx: &mut NodeCtx,
    ) -> Result<()>;

    /// Parameterized nodes report their monotone update counter; the
    /// runtime stamps it onto every forward emission (the staleness wire
    /// protocol's version tag). `None` for glue/control nodes.
    fn version(&self) -> Option<u64> {
        None
    }

    /// Parameter access for replica averaging / checkpointing. Nodes
    /// without parameters return an empty vec.
    fn params(&self) -> Vec<Tensor> {
        Vec::new()
    }

    fn set_params(&mut self, _params: Vec<Tensor>) {}

    /// Flush a pending partial gradient accumulation (end of epoch).
    fn flush(&mut self, _ctx: &mut NodeCtx) -> Result<()> {
        Ok(())
    }

    /// Capture the node's current parameters as the serving snapshot
    /// (CoW Arc clone — a refcount bump per tensor, DESIGN.md §15).
    /// Inference-lane forwards read this snapshot instead of the live
    /// parameters. No-op for nodes without parameters.
    fn snapshot_params(&mut self) {}

    /// Export optimizer state for checkpointing (`None` for nodes
    /// without parameters).
    fn opt_state(&self) -> Option<crate::optim::OptState> {
        None
    }

    /// Restore optimizer state exported by [`Node::opt_state`].
    fn set_opt_state(&mut self, _state: crate::optim::OptState) -> Result<()> {
        Ok(())
    }

    /// Node-private cached keys. Most nodes keep all per-instance state
    /// in the runtime stash (counted by [`NodeRt::cached`]); this covers
    /// any private residue. Engines report the sum.
    fn cached_keys(&self) -> usize {
        0
    }

    fn name(&self) -> &str;
}

/// One node plus its placement and its runtime-owned state.
pub struct NodeSlot {
    pub node: Box<dyn Node>,
    /// The node runtime's per-node ledger/stash (metadata threading and
    /// per-instance caches — see [`crate::ir::rt`]).
    pub rt: NodeRt,
    pub worker: WorkerId,
    pub label: String,
    /// The builder-declared static FLOP estimate ([`super::build::NodeSpec::cost`]),
    /// kept on the built graph so measured-cost tooling (calibration
    /// profiles, LPT over measured costs) can fall back to it for nodes
    /// a short calibration run never touched.
    pub cost: u64,
}

/// The static graph. Built once per model; the engines consume it.
pub struct Graph {
    pub nodes: Vec<NodeSlot>,
    /// fwd_edges[node][out_port] => where forward output goes.
    pub fwd_edges: Vec<Vec<Option<(NodeId, PortId)>>>,
    /// bwd_edges[node][in_port] => where backward output goes
    /// (None = controller boundary: the input was pumped).
    pub bwd_edges: Vec<Vec<Option<(NodeId, PortId)>>>,
    pub n_workers: usize,
}

impl Graph {
    /// Resolve a node-emitted (port, message) into a concrete route.
    pub fn resolve(&self, from: NodeId, port: PortId, dir: Dir) -> Endpoint {
        let table = match dir {
            Dir::Fwd => &self.fwd_edges,
            Dir::Bwd => &self.bwd_edges,
        };
        match table[from].get(port).copied().flatten() {
            Some((n, p)) => Endpoint::Node(n, p),
            None => Endpoint::Controller,
        }
    }

    pub fn worker_of(&self, node: NodeId) -> WorkerId {
        self.nodes[node].worker
    }

    pub fn label(&self, node: NodeId) -> &str {
        &self.nodes[node].label
    }

    /// Reassign every node's worker in place (placement search evaluates
    /// many candidate assignments against one built graph instead of
    /// rebuilding model + datasets per candidate). Workers must be in
    /// range; the routing tables are placement-independent and unchanged.
    pub fn set_workers(&mut self, workers: &[WorkerId]) {
        assert_eq!(workers.len(), self.nodes.len(), "one worker per node");
        for (slot, &w) in self.nodes.iter_mut().zip(workers) {
            assert!(w < self.n_workers, "worker {w} out of range");
            slot.worker = w;
        }
    }
}

/// Initial messages the controller injects for one instance: typed
/// envelopes `(node, in-port, state, payload)` plus the lane the whole
/// instance travels in. Pumpers never construct [`Message`]s — the
/// engines materialize them with the right [`MsgMeta`] at injection.
/// Cloning is cheap (`Tensor` payloads are `Arc`-backed) — the
/// controller's recovery ledger keeps a clone per in-flight instance
/// so a lost worker's instances can be re-admitted.
#[derive(Clone)]
pub struct PumpSet {
    pub envelopes: Vec<(NodeId, PortId, MsgState, Vec<Tensor>)>,
    /// Stream class of the instance (non-Train lanes are forward-only:
    /// metrics/response at the loss layer, no backprop).
    pub lane: Lane,
    /// Forward-only retire condition: number of loss events this
    /// instance produces (the Train lane uses `expected_bwd()` instead).
    pub eval_expected: usize,
    /// Serving deadline tag in µs from admission (0 = none; only the
    /// Infer lane sets it).
    pub deadline_us: u32,
}

impl PumpSet {
    /// Two-lane compatibility constructor (true = train, false = eval) —
    /// what every model pumper uses.
    pub fn new(train: bool) -> Self {
        PumpSet::for_lane(if train { Lane::Train } else { Lane::Eval })
    }

    pub fn for_lane(lane: Lane) -> Self {
        PumpSet { envelopes: Vec::new(), lane, eval_expected: 1, deadline_us: 0 }
    }

    pub fn push(&mut self, node: NodeId, port: PortId, state: MsgState, payload: Vec<Tensor>) {
        self.envelopes.push((node, port, state, payload));
    }

    /// Retag an existing pump onto another lane (builder-style). The
    /// serving front-end turns a model pumper's eval pump into an
    /// inference request this way, so pumpers stay lane-agnostic.
    pub fn into_lane(mut self, lane: Lane, deadline_us: u32) -> Self {
        self.lane = lane;
        self.deadline_us = deadline_us;
        self
    }

    /// Rewrite every envelope's instance id (builder-style). Serving
    /// requests draw ids from a disjoint range so they can never collide
    /// with plan-order train/eval ids in the controller's accounting.
    pub fn with_instance(mut self, instance: u64) -> Self {
        for env in &mut self.envelopes {
            env.2.instance = instance;
        }
        self
    }

    /// Training retire condition: one backward per pumped message
    /// (the paper's forward/backward state invariant).
    pub fn expected_bwd(&self) -> usize {
        self.envelopes.len()
    }

    /// The instance id (from the first envelope's state).
    pub fn instance(&self) -> u64 {
        self.envelopes.first().expect("empty PumpSet").2.instance
    }

    /// Materialize the controller messages (engine injection).
    pub fn into_messages(self) -> impl Iterator<Item = (NodeId, PortId, Message)> {
        let meta = MsgMeta { deadline_us: self.deadline_us, ..MsgMeta::for_lane(self.lane) };
        self.envelopes.into_iter().map(move |(node, port, state, payload)| {
            (node, port, Message { dir: Dir::Fwd, state, payload, meta })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::build::{NetBuilder, NodeSpec, Pinned};

    struct Dummy;
    impl Node for Dummy {
        fn forward(
            &mut self,
            _p: PortId,
            s: MsgState,
            payload: Vec<Tensor>,
            c: &mut NodeCtx,
        ) -> Result<()> {
            c.emit_fwd(0, s, payload);
            Ok(())
        }
        fn backward(
            &mut self,
            _p: PortId,
            s: MsgState,
            payload: Vec<Tensor>,
            c: &mut NodeCtx,
        ) -> Result<()> {
            c.emit_bwd(0, s, payload);
            Ok(())
        }
        fn name(&self) -> &str {
            "dummy"
        }
    }

    // These cover the Graph-side contract (routing tables, resolve,
    // controller boundary); they were formerly written against the
    // deleted legacy `GraphBuilder` shim and now build through
    // `NetBuilder` like all production code.
    #[test]
    fn built_graph_resolves_both_directions() {
        let mut b = NetBuilder::new();
        let a = b.add(NodeSpec::new("a").pin(0), Box::new(Dummy));
        let z = b.add(NodeSpec::new("z").pin(1).outputs(0), Box::new(Dummy));
        b.wire(a.out(0), z.input(0));
        b.controller_input(a.input(0));
        let graph = b.build(2, &Pinned).unwrap().graph;
        assert_eq!(graph.resolve(a.id(), 0, Dir::Fwd), Endpoint::Node(z.id(), 0));
        assert_eq!(graph.resolve(z.id(), 0, Dir::Bwd), Endpoint::Node(a.id(), 0));
        // a's input is pumped => backward out of it hits the controller
        assert_eq!(graph.resolve(a.id(), 0, Dir::Bwd), Endpoint::Controller);
        assert_eq!(graph.worker_of(z.id()), 1);
        assert_eq!(graph.label(a.id()), "a");
    }

    #[test]
    fn double_wiring_is_rejected_at_build() {
        let mut b = NetBuilder::new();
        let a = b.add(NodeSpec::new("a"), Box::new(Dummy));
        let z = b.add(NodeSpec::new("z").inputs(2).outputs(0), Box::new(Dummy));
        b.wire(a.out(0), z.input(0));
        b.wire(a.out(0), z.input(1));
        b.controller_input(a.input(0));
        let err = b.build(1, &Pinned).unwrap_err();
        assert!(format!("{err:#}").contains("wired twice"), "{err:#}");
    }

    #[test]
    fn pump_set_counts_expected_backwards() {
        let mut p = PumpSet::new(true);
        assert_eq!(p.expected_bwd(), 0);
        p.push(0, 0, MsgState::for_instance(1), vec![]);
        p.push(1, 0, MsgState::for_instance(1), vec![]);
        assert_eq!(p.expected_bwd(), 2);
        assert_eq!(p.eval_expected, 1);
        assert_eq!(p.instance(), 1);
    }

    #[test]
    fn pump_set_materializes_mode_tagged_messages() {
        let mut p = PumpSet::new(false);
        p.push(3, 1, MsgState::for_instance(7), vec![Tensor::scalar(2.0)]);
        let msgs: Vec<_> = p.into_messages().collect();
        assert_eq!(msgs.len(), 1);
        let (node, port, msg) = &msgs[0];
        assert_eq!((*node, *port), (3, 1));
        assert_eq!(msg.dir, Dir::Fwd);
        assert!(!msg.is_train());
        assert_eq!(msg.version(), None);
    }

    #[test]
    fn pump_set_retags_lane_and_instance() {
        let mut p = PumpSet::new(false);
        p.push(0, 0, MsgState::for_instance(7), vec![]);
        p.push(1, 0, MsgState::for_instance(7), vec![]);
        let p = p.into_lane(Lane::Infer, 2500).with_instance(1 << 62);
        assert_eq!(p.lane, Lane::Infer);
        assert_eq!(p.instance(), 1 << 62);
        let msgs: Vec<_> = p.into_messages().collect();
        assert!(msgs.iter().all(|(_, _, m)| m.lane() == Lane::Infer));
        assert!(msgs.iter().all(|(_, _, m)| m.meta.deadline_us == 2500));
        assert!(msgs.iter().all(|(_, _, m)| m.state.instance == 1 << 62));
    }
}
