//! The static IR graph: nodes, typed ports, and the routing tables both
//! execution engines (threaded and simulated) share.

use std::sync::mpsc::Sender;

use anyhow::Result;

use crate::runtime::Backend;
use crate::tensor::Tensor;

use super::message::{Dir, Message};
use super::state::MsgState;

pub type NodeId = usize;
pub type PortId = usize;
pub type WorkerId = usize;

/// Where a message is headed next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Endpoint {
    /// (node, port). For `Dir::Fwd` the port is the target's *input* port;
    /// for `Dir::Bwd` it is the target's *output* port the cotangent
    /// corresponds to.
    Node(NodeId, PortId),
    /// Back to the controller (graph boundary). Forward messages never
    /// route here; backward messages arriving here retire pumped inputs.
    Controller,
}

/// A routed message produced by a node.
#[derive(Debug)]
pub struct Route {
    pub to: Endpoint,
    pub msg: Message,
}

/// Events emitted by nodes toward the controller (out-of-band of the
/// message graph; in a distributed deployment these are the telemetry
/// channel back to the leader).
#[derive(Clone, Debug)]
pub enum Event {
    /// Loss layer processed one (prediction, label) pair.
    Loss {
        instance: u64,
        loss: f32,
        /// #correct and #examples for classification; (0, n) for regression.
        correct: u32,
        count: u32,
        /// Sum of absolute errors (regression only; 0 for classification).
        abs_err: f32,
        train: bool,
    },
    /// A parameterized node applied an accumulated update.
    Update { node: NodeId, staleness_sum: u64, staleness_n: u32 },
    /// Eval-mode instance finished at the loss layer.
    EvalDone { instance: u64 },
}

/// Where node events go. Implemented for plain mpsc senders (sim engine,
/// unit tests) and for the threaded engine's merged controller channel.
pub trait EventSink {
    fn send_event(&self, ev: Event);
}

impl EventSink for Sender<Event> {
    fn send_event(&self, ev: Event) {
        // The controller may have hung up after training; ignore.
        let _ = self.send(ev);
    }
}

/// Per-invocation context handed to nodes: the worker's backend plus the
/// event channel. (Parameters live *inside* PPT nodes — the paper's local
/// update rule — so no parameter server appears here.)
pub struct NodeCtx<'a> {
    pub backend: &'a mut dyn Backend,
    pub events: &'a dyn EventSink,
    pub node_id: NodeId,
}

impl<'a> NodeCtx<'a> {
    pub fn emit(&self, ev: Event) {
        self.events.send_event(ev);
    }
}

/// An IR node: a state machine processing forward/backward messages.
/// `port` identifies which input (fwd) or output (bwd) the message
/// arrived on.
pub trait Node: Send {
    fn forward(&mut self, port: PortId, msg: Message, ctx: &mut NodeCtx) -> Result<Vec<(PortId, Message)>>;

    fn backward(&mut self, port: PortId, msg: Message, ctx: &mut NodeCtx) -> Result<Vec<(PortId, Message)>>;

    /// Parameter access for replica averaging / checkpointing. Nodes
    /// without parameters return an empty vec.
    fn params(&self) -> Vec<Tensor> {
        Vec::new()
    }

    fn set_params(&mut self, _params: Vec<Tensor>) {}

    /// Flush a pending partial gradient accumulation (end of epoch).
    fn flush(&mut self, _ctx: &mut NodeCtx) -> Result<()> {
        Ok(())
    }

    /// Number of cached keys (leak detection in tests).
    fn cached_keys(&self) -> usize {
        0
    }

    fn name(&self) -> &str;
}

/// One node plus its placement.
pub struct NodeSlot {
    pub node: Box<dyn Node>,
    pub worker: WorkerId,
    pub label: String,
}

/// The static graph. Built once per model; the engines consume it.
pub struct Graph {
    pub nodes: Vec<NodeSlot>,
    /// fwd_edges[node][out_port] => where forward output goes.
    pub fwd_edges: Vec<Vec<Option<(NodeId, PortId)>>>,
    /// bwd_edges[node][in_port] => where backward output goes
    /// (None = controller boundary: the input was pumped).
    pub bwd_edges: Vec<Vec<Option<(NodeId, PortId)>>>,
    pub n_workers: usize,
}

impl Graph {
    /// Resolve a node-emitted (port, message) into a concrete route.
    pub fn resolve(&self, from: NodeId, port: PortId, dir: Dir) -> Endpoint {
        let table = match dir {
            Dir::Fwd => &self.fwd_edges,
            Dir::Bwd => &self.bwd_edges,
        };
        match table[from].get(port).copied().flatten() {
            Some((n, p)) => Endpoint::Node(n, p),
            None => Endpoint::Controller,
        }
    }

    pub fn worker_of(&self, node: NodeId) -> WorkerId {
        self.nodes[node].worker
    }

    pub fn label(&self, node: NodeId) -> &str {
        &self.nodes[node].label
    }
}

/// Legacy builder over raw `(NodeId, PortId)` pairs. Performs **no**
/// build-time validation (asserts fire on double-wiring only); kept as a
/// compatibility shim for out-of-tree callers.
#[deprecated(
    since = "0.1.0",
    note = "use ir::build::NetBuilder: typed port handles, pluggable placement, \
            and a real validation pass at build()"
)]
pub struct GraphBuilder {
    slots: Vec<NodeSlot>,
    fwd: Vec<Vec<Option<(NodeId, PortId)>>>,
    bwd: Vec<Vec<Option<(NodeId, PortId)>>>,
    n_workers: usize,
}

#[allow(deprecated)]
impl GraphBuilder {
    pub fn new(n_workers: usize) -> Self {
        assert!(n_workers > 0);
        GraphBuilder { slots: Vec::new(), fwd: Vec::new(), bwd: Vec::new(), n_workers }
    }

    /// Add a node affinitized to `worker`. Returns its id.
    pub fn add(&mut self, label: &str, worker: WorkerId, node: Box<dyn Node>) -> NodeId {
        assert!(worker < self.n_workers, "worker {worker} out of range");
        let id = self.slots.len();
        self.slots.push(NodeSlot { node, worker, label: label.to_string() });
        self.fwd.push(Vec::new());
        self.bwd.push(Vec::new());
        id
    }

    /// Connect src's output `src_port` to dst's input `dst_port`.
    /// Forward messages flow src→dst; backward messages dst→src.
    pub fn connect(&mut self, src: NodeId, src_port: PortId, dst: NodeId, dst_port: PortId) {
        let f = &mut self.fwd[src];
        if f.len() <= src_port {
            f.resize(src_port + 1, None);
        }
        assert!(f[src_port].is_none(), "output port {src_port} of node {src} already connected");
        f[src_port] = Some((dst, dst_port));
        let b = &mut self.bwd[dst];
        if b.len() <= dst_port {
            b.resize(dst_port + 1, None);
        }
        assert!(b[dst_port].is_none(), "input port {dst_port} of node {dst} already connected");
        b[dst_port] = Some((src, src_port));
    }

    /// Declare that dst's input `dst_port` is pumped by the controller.
    /// NOTE: this shim only asserts the port is not already wired — it
    /// records nothing and `build()` validates nothing. The replacement,
    /// [`crate::ir::build::NetBuilder::controller_input`], carries the
    /// declaration into a real build-time validation pass.
    pub fn controller_input(&mut self, dst: NodeId, dst_port: PortId) {
        let b = &mut self.bwd[dst];
        if b.len() <= dst_port {
            b.resize(dst_port + 1, None);
        }
        assert!(b[dst_port].is_none(), "input {dst_port} of node {dst} already wired");
    }

    pub fn build(self) -> Graph {
        Graph { nodes: self.slots, fwd_edges: self.fwd, bwd_edges: self.bwd, n_workers: self.n_workers }
    }
}

/// Helper: initial messages the controller injects for one instance.
pub struct PumpSet {
    pub envelopes: Vec<(NodeId, PortId, Message)>,
    /// Eval-mode retire condition: number of loss events this instance
    /// produces (train mode uses `expected_bwd()` instead).
    pub eval_expected: usize,
}

impl PumpSet {
    pub fn new() -> Self {
        PumpSet { envelopes: Vec::new(), eval_expected: 1 }
    }

    pub fn push(&mut self, node: NodeId, port: PortId, msg: Message) {
        self.envelopes.push((node, port, msg));
    }

    /// Training retire condition: one backward per pumped message
    /// (the paper's forward/backward state invariant).
    pub fn expected_bwd(&self) -> usize {
        self.envelopes.len()
    }
}

impl Default for PumpSet {
    fn default() -> Self {
        Self::new()
    }
}

/// Build a forward pump message.
pub fn pump_msg(state: MsgState, payload: Vec<Tensor>, train: bool) -> Message {
    if train {
        Message::fwd(state, payload)
    } else {
        Message::eval(state, payload)
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;

    struct Dummy;
    impl Node for Dummy {
        fn forward(&mut self, _p: PortId, m: Message, _c: &mut NodeCtx) -> Result<Vec<(PortId, Message)>> {
            Ok(vec![(0, m)])
        }
        fn backward(&mut self, _p: PortId, m: Message, _c: &mut NodeCtx) -> Result<Vec<(PortId, Message)>> {
            Ok(vec![(0, m)])
        }
        fn name(&self) -> &str {
            "dummy"
        }
    }

    #[test]
    fn builder_wires_both_directions() {
        let mut g = GraphBuilder::new(2);
        let a = g.add("a", 0, Box::new(Dummy));
        let b = g.add("b", 1, Box::new(Dummy));
        g.connect(a, 0, b, 0);
        let graph = g.build();
        assert_eq!(graph.resolve(a, 0, Dir::Fwd), Endpoint::Node(b, 0));
        assert_eq!(graph.resolve(b, 0, Dir::Bwd), Endpoint::Node(a, 0));
        // a's input is unwired => controller boundary
        assert_eq!(graph.resolve(a, 0, Dir::Bwd), Endpoint::Controller);
        assert_eq!(graph.worker_of(b), 1);
    }

    #[test]
    #[should_panic(expected = "already connected")]
    fn double_connect_is_rejected() {
        let mut g = GraphBuilder::new(1);
        let a = g.add("a", 0, Box::new(Dummy));
        let b = g.add("b", 0, Box::new(Dummy));
        g.connect(a, 0, b, 0);
        g.connect(a, 0, b, 1);
    }
}
