//! Shared launcher plumbing used by the CLI, examples and benches:
//! dataset scaling, backend selection, and model construction by name
//! with the paper's per-model default hyperparameters.

use std::sync::Arc;

use anyhow::Result;

use crate::data::{ListRedGen, MnistLike, SentiTreeGen};
use crate::models::{ggsnn, mlp, rnn, tree_lstm, BuiltModel, ModelCfg};
use crate::runtime::{BackendKind, BackendSpec, Manifest};
use crate::train::TargetMetric;
use crate::util::Args;

pub fn backend_spec(args: &Args) -> Result<BackendSpec> {
    // Precedence: --backend flag, then $AMP_BACKEND (CI runs the
    // examples artifact-free with AMP_BACKEND=native), then xla.
    let kind: BackendKind = match args.get("backend") {
        Some(v) => v.parse()?,
        None => std::env::var("AMP_BACKEND").unwrap_or_else(|_| "xla".into()).parse()?,
    };
    let manifest = match kind {
        BackendKind::Xla => Arc::new(Manifest::load_default()?),
        BackendKind::Native => Arc::new(Manifest::empty()),
    };
    Ok(BackendSpec::new(kind, manifest))
}

/// Dataset scale factor (`AMP_SCALE`): benches/CI shrink the paper-sized
/// datasets; 1.0 reproduces the paper's instance counts.
pub fn scale() -> f64 {
    std::env::var("AMP_SCALE").ok().and_then(|v| v.parse().ok()).unwrap_or(0.05)
}

pub fn scaled(n: usize) -> usize {
    ((n as f64 * scale()) as usize).max(1)
}

/// Build a model + its Table-1 target metric by name, with per-model
/// default hyperparameters (overridable by CLI args, including
/// `--placement round-robin|pinned|cost`, `--flavor xla|pallas` and
/// `--staleness ignore|lr-discount[:alpha]|clip[:max]`). Trainer-side
/// axes (`--admission`, `--stream`, `--eval-interleave gated|live`) are
/// parsed by the CLI/examples into [`crate::train::TrainCfg`].
pub fn build_model(name: &str, args: &Args, workers: usize) -> Result<(BuiltModel, TargetMetric)> {
    let mut mcfg = ModelCfg::default();
    mcfg.muf = args.usize_or("muf", 100);
    mcfg.lr = args.f32_or("lr", 0.1);
    mcfg.seed = args.u64_or("seed", 42);
    let mut pinned_file = None;
    if let Some(p) = args.get("placement") {
        // `pinned:<path>` loads a tuned placement file emitted by
        // `ampnet tune-placement`. The raw value ships verbatim to remote
        // workers via [`model_args_string`], so the path must resolve on
        // every worker host (shared filesystem or per-host copy).
        if let Some(path) = p.strip_prefix("pinned:") {
            let pf = crate::placement::PlacementFile::load(path)?;
            mcfg.assignment = Some(Arc::new(pf.assignment.clone()));
            pinned_file = Some(pf);
        } else {
            mcfg.placement = p.parse()?;
        }
    }
    let mut cost_profile = None;
    if let Some(path) = args.get("cost-profile") {
        let profile = crate::placement::CostProfile::load(path)?;
        mcfg.measured_costs = Some(Arc::new(profile.measured_costs()));
        cost_profile = Some(profile);
    }
    if let Some(f) = args.get("flavor") {
        mcfg.flavor = f.parse()?;
    }
    if let Some(s) = args.get("staleness") {
        mcfg.staleness = s.parse()?;
    }
    let built = match name {
        "mlp" => {
            let data = MnistLike::new(mcfg.seed, scaled(60_000), scaled(10_000).max(500), 100);
            (
                mlp::build(&mcfg, data, workers)?,
                TargetMetric::Accuracy(args.f32_or("target", 0.97) as f64),
            )
        }
        "rnn" => {
            mcfg.lr = args.f32_or("lr", 0.5);
            let data = ListRedGen::new(mcfg.seed, scaled(100_000), scaled(10_000).max(500), 100);
            let replicas = args.usize_or("replicas", 1);
            (
                rnn::build(&mcfg, data, workers, replicas)?,
                TargetMetric::Accuracy(args.f32_or("target", 0.97) as f64),
            )
        }
        "tree" => {
            mcfg.lr = args.f32_or("lr", 0.01);
            mcfg.muf = args.usize_or("muf", 50);
            let gen = SentiTreeGen::new(mcfg.seed, scaled(8544), scaled(1101).max(64));
            (
                tree_lstm::build(&mcfg, gen, workers)?,
                TargetMetric::Accuracy(args.f32_or("target", 0.82) as f64),
            )
        }
        "babi" => {
            mcfg.lr = args.f32_or("lr", 0.005);
            mcfg.muf = args.usize_or("muf", 10);
            let src = ggsnn::babi_source(mcfg.seed, scaled(2000).max(50), scaled(1000).max(32));
            (
                ggsnn::build(&mcfg, ggsnn::GgsnnTask::Babi, src, workers)?,
                TargetMetric::Accuracy(args.f32_or("target", 1.0) as f64),
            )
        }
        "qm9" => {
            mcfg.lr = args.f32_or("lr", 0.003);
            mcfg.muf = args.usize_or("muf", 20);
            let src = ggsnn::qm9_source(mcfg.seed, scaled(117_000), scaled(13_000).max(64));
            (
                ggsnn::build(&mcfg, ggsnn::GgsnnTask::Qm9, src, workers)?,
                TargetMetric::MaeRatio {
                    ratio: args.f32_or("target", 4.6) as f64,
                    unit: crate::data::graphs::QM9_TARGET_UNIT as f64,
                },
            )
        }
        other => anyhow::bail!("unknown model '{other}' (mlp|rnn|tree|babi|qm9)"),
    };
    // A placement/profile tuned for a different topology (other model,
    // other worker count, changed graph) must fail loudly, not silently
    // misplace; the fingerprint is placement-independent, so validating
    // against the just-built graph is sound even though the assignment
    // was already applied.
    if let Some(pf) = &pinned_file {
        pf.validate(&built.0.graph)?;
    }
    if let Some(profile) = &cost_profile {
        profile.validate(&built.0.graph)?;
    }
    Ok(built)
}

/// Parse args from a whitespace-separated string (benches/examples).
pub fn args_from(s: &str) -> Args {
    Args::parse(s.split_whitespace().map(String::from))
}

/// Render the model-relevant subset of `args` back into a CLI string —
/// the inverse of [`args_from`] over the keys [`build_model`] reads.
/// Shipped to remote workers in the transport `Hello` handshake so their
/// shared-nothing rebuild sees the head's exact model configuration.
pub fn model_args_string(args: &Args) -> String {
    const KEYS: [&str; 9] = [
        "muf",
        "lr",
        "seed",
        "placement",
        "cost-profile",
        "flavor",
        "staleness",
        "replicas",
        "target",
    ];
    let mut parts = Vec::new();
    for k in KEYS {
        if let Some(v) = args.get(k) {
            parts.push(format!("--{k} {v}"));
        }
    }
    parts.join(" ")
}

/// Write `json` to `<dir>/<name>.json`, creating the directory.
pub fn write_json_to(
    dir: impl AsRef<std::path::Path>,
    name: &str,
    json: &crate::util::json::Json,
) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, json.to_string())?;
    log::info!("report written to {}", path.display());
    Ok(())
}

/// Write `json` to `$AMP_REPORT_DIR/<name>.json` when that env var is
/// set (the CI examples-smoke job collects these as artifacts); no-op
/// otherwise, so local runs stay file-free.
pub fn maybe_write_json(name: &str, json: &crate::util::json::Json) -> Result<()> {
    match std::env::var("AMP_REPORT_DIR") {
        Ok(dir) => write_json_to(dir, name, json),
        Err(_) => Ok(()),
    }
}

/// [`maybe_write_json`] for a trainer run report.
pub fn maybe_write_report(name: &str, report: &crate::train::RunReport) -> Result<()> {
    maybe_write_json(name, &report.to_json())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_every_model() {
        std::env::set_var("AMP_SCALE", "0.001");
        for name in ["mlp", "rnn", "tree", "babi", "qm9"] {
            let (m, _t) = build_model(name, &args_from(""), 8).unwrap();
            assert!(!m.graph.nodes.is_empty(), "{name}");
        }
        assert!(build_model("nope", &args_from(""), 8).is_err());
    }

    #[test]
    fn report_json_written_to_directory() {
        // Tests the env-free writer directly: mutating AMP_REPORT_DIR
        // here would race other tests in this binary (env is
        // process-global under the parallel test harness).
        let report = crate::train::RunReport { name: "unit".into(), ..Default::default() };
        let dir = std::env::temp_dir().join(format!("amp_reports_{}", std::process::id()));
        write_json_to(&dir, "unit", &report.to_json()).unwrap();
        let body = std::fs::read_to_string(dir.join("unit.json")).unwrap();
        assert!(body.contains("\"name\":\"unit\""), "{body}");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn staleness_flag_reaches_model_cfg() {
        std::env::set_var("AMP_SCALE", "0.001");
        // parses and builds; the policy itself is exercised in
        // optim/scheduler tests
        let (m, _) = build_model("mlp", &args_from("--staleness lr-discount:0.25"), 4).unwrap();
        assert!(!m.graph.nodes.is_empty());
        assert!(build_model("mlp", &args_from("--staleness bogus"), 4).is_err());
    }

    #[test]
    fn placement_flag_selects_strategy() {
        std::env::set_var("AMP_SCALE", "0.001");
        let (pinned, _) =
            build_model("qm9", &args_from("--placement pinned"), 8).unwrap();
        let (cost, _) = build_model("qm9", &args_from("--placement cost"), 8).unwrap();
        let w = |m: &crate::models::BuiltModel| {
            m.graph.nodes.iter().map(|s| s.worker).collect::<Vec<_>>()
        };
        assert_ne!(w(&pinned), w(&cost));
        assert!(build_model("mlp", &args_from("--placement nope"), 8).is_err());
    }
}
