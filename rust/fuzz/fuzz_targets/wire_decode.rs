//! Fuzz the wire-frame decoder: arbitrary bytes must produce
//! `Ok`/`Err`, never a panic, abort, or unbounded allocation. The
//! decoder's length field is attacker-controlled here, so this also
//! exercises the `MAX_FRAME` backstop and the trailing-bytes check.

#![no_main]

use libfuzzer_sys::fuzz_target;

use ampnet::transport::wire::decode_frame;

fuzz_target!(|data: &[u8]| {
    if let Ok((frame, used)) = decode_frame(data) {
        // A successful decode must account for a prefix of the input and
        // survive being formatted (Debug walks every payload field).
        assert!(used <= data.len());
        let _ = format!("{frame:?}");
    }
});
