//! Fuzz the NetBuilder validation pass: an arbitrary byte string is
//! interpreted as a graph recipe (node arities, pins, dims, edges, pump
//! ports, placement choice) and built. Malformed wiring — dangling
//! ports, double wiring, out-of-range ports, shape mismatches, bad pins
//! — must come back as `Err`, never as a panic inside `build()`.

#![no_main]

use libfuzzer_sys::fuzz_target;

use ampnet::ir::nodes::IsuNode;
use ampnet::ir::{NetBuilder, NodeSpec, PlacementKind};

struct Bytes<'a> {
    data: &'a [u8],
    pos: usize,
}

impl Bytes<'_> {
    fn next(&mut self) -> u8 {
        let b = self.data.get(self.pos).copied().unwrap_or(0);
        self.pos += 1;
        b
    }
}

fuzz_target!(|data: &[u8]| {
    let mut b = Bytes { data, pos: 0 };
    let n = 1 + (b.next() as usize % 8);
    let mut builder = NetBuilder::new();
    let mut handles = Vec::with_capacity(n);
    for i in 0..n {
        let label = format!("n{i}");
        let mut spec = NodeSpec::new(&label)
            .inputs(b.next() as usize % 4)
            .outputs(b.next() as usize % 4)
            .cost(b.next() as u64);
        let pin = b.next();
        if pin & 1 == 1 {
            spec = spec.pin((pin >> 1) as usize % 6);
        }
        let d = b.next();
        if d & 1 == 1 {
            spec = spec.out_dim((d as usize >> 1) % 3, 1 + d as usize);
        }
        let d = b.next();
        if d & 1 == 1 {
            spec = spec.in_dim((d as usize >> 1) % 3, 1 + d as usize);
        }
        handles.push(builder.add(spec, Box::new(IsuNode::incr_t(&label))));
    }
    for _ in 0..b.next() as usize % 16 {
        let from = handles[b.next() as usize % n];
        let to = handles[b.next() as usize % n];
        builder.wire(from.out(b.next() as usize % 5), to.input(b.next() as usize % 5));
    }
    for _ in 0..b.next() as usize % 8 {
        let node = handles[b.next() as usize % n];
        builder.controller_input(node.input(b.next() as usize % 5));
    }
    if b.next() & 1 == 1 {
        builder.replica_group(&handles);
    }
    let workers = 1 + b.next() as usize % 4;
    let kind = PlacementKind::ALL[b.next() as usize % PlacementKind::ALL.len()];
    // Valid or not, build() must diagnose — never panic.
    let _ = builder.build(workers, kind.strategy().as_ref());
});
