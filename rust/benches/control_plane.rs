//! Control-plane bench: steady-state occupancy, message throughput and
//! applied staleness per (admission x staleness) policy pair, on the MLP
//! with the native backend (no AOT artifacts needed).
//!
//! Emits a machine-readable `BENCH_control_plane.json` next to the
//! human-readable table so the perf trajectory of the control plane is
//! tracked across PRs. Override the output path with `AMP_BENCH_OUT`.
//!
//! Compare the `fixed` row (per-epoch drain-to-zero, the paper's
//! behavior) against the streaming `aimd` rows: equal MAK ceiling,
//! higher mean occupancy, bounded mean staleness.

use ampnet::data::{MnistLike, Split};
use ampnet::ir::PumpSet;
use ampnet::models::{mlp, ModelCfg, Pumper};
use ampnet::runtime::BackendSpec;
use ampnet::scheduler::{
    build_engine, AdmissionKind, EngineKind, EpochKind, EpochStats, StalenessKind, StreamPlan,
};
use ampnet::util::json::{self, Json};
use anyhow::Result;

const MAK: usize = 4;
const EPOCHS: usize = 6;
const TRAIN: usize = 800; // 8 batches of 100 per epoch
const WORKERS: usize = 4;

struct Row {
    admission: AdmissionKind,
    staleness: StalenessKind,
    streamed: bool,
    occupancy: f64,
    msgs_per_sec: f64,
    mean_staleness: f64,
    staleness_max: u64,
    grads_dropped: u64,
    instances: usize,
    virtual_s: f64,
}

fn run(admission: AdmissionKind, staleness: StalenessKind, streamed: bool) -> Result<Row> {
    let mut mcfg = ModelCfg::default();
    mcfg.muf = 100; // one update per batched backward: staleness is visible
    mcfg.lr = 0.05;
    mcfg.staleness = staleness;
    let model = mlp::build(&mcfg, MnistLike::new(0, TRAIN, 200, 100), WORKERS)?;
    let mut eng = build_engine(EngineKind::Sim, model.graph, BackendSpec::native(), false)?;
    let pumps_of = |pumper: &dyn Pumper| -> Vec<PumpSet> {
        (0..pumper.n(Split::Train)).map(|i| pumper.pump(Split::Train, i)).collect()
    };
    let stats: Vec<EpochStats> = if streamed {
        let epochs: Vec<Vec<PumpSet>> =
            (0..EPOCHS).map(|_| pumps_of(model.pumper.as_ref())).collect();
        let mut policy = admission.policy(MAK);
        eng.run_stream(StreamPlan::train(epochs), policy.as_mut())?
    } else {
        // the classic drain-to-zero cycle: one run_epoch call per epoch
        (0..EPOCHS)
            .map(|_| eng.run_epoch(pumps_of(model.pumper.as_ref()), MAK, EpochKind::Train))
            .collect::<Result<_>>()?
    };
    anyhow::ensure!(eng.cached_keys()? == 0, "leaked keys");
    let m = EpochStats::merged(&stats);
    Ok(Row {
        admission,
        staleness,
        streamed,
        occupancy: m.mean_occupancy(),
        msgs_per_sec: m.msgs_per_sec(),
        mean_staleness: m.mean_staleness(),
        staleness_max: m.staleness_max,
        grads_dropped: m.grads_dropped,
        instances: m.instances,
        virtual_s: m.virtual_seconds,
    })
}

fn main() -> Result<()> {
    ampnet::util::logging::init();
    println!("== Control plane: occupancy / throughput / staleness per policy ==");
    println!(
        "   (mlp, native backend, mak ceiling {MAK}, {EPOCHS} epochs x {} instances)",
        TRAIN / 100
    );
    let configs = [
        (AdmissionKind::Fixed, StalenessKind::Ignore, false),
        (AdmissionKind::Fixed, StalenessKind::Ignore, true),
        (AdmissionKind::Aimd { staleness_bound: 6.0 }, StalenessKind::Ignore, true),
        (
            AdmissionKind::Aimd { staleness_bound: 6.0 },
            StalenessKind::LrDiscount { alpha: 0.5 },
            true,
        ),
        (AdmissionKind::Fixed, StalenessKind::Clip { max_staleness: 2 }, true),
    ];
    let mut rows = Vec::new();
    for (admission, staleness, streamed) in configs {
        let r = run(admission, staleness, streamed)?;
        println!(
            "admission={:<10} staleness={:<16} {} occ={:.2} msgs/s={:>9.0} stale(mean={:.2} max={}) dropped={} inst={}",
            r.admission.to_string(),
            r.staleness.to_string(),
            if r.streamed { "stream" } else { "drain " },
            r.occupancy,
            r.msgs_per_sec,
            r.mean_staleness,
            r.staleness_max,
            r.grads_dropped,
            r.instances,
        );
        rows.push(r);
    }

    // Machine-checkable property: every config processed the full
    // workload and produced a meaningful occupancy signal.
    assert!(rows.iter().all(|r| r.instances == EPOCHS * TRAIN / 100));
    assert!(rows.iter().all(|r| r.occupancy > 0.0 && r.occupancy <= MAK as f64 + 1e-9));

    let out = json::obj(vec![
        ("bench", json::s("control_plane")),
        ("model", json::s("mlp-mnist")),
        ("mak", json::num(MAK as f64)),
        ("epochs", json::num(EPOCHS as f64)),
        ("workers", json::num(WORKERS as f64)),
        (
            "configs",
            json::arr(rows.iter().map(|r| {
                json::obj(vec![
                    ("admission", json::s(&r.admission.to_string())),
                    ("staleness", json::s(&r.staleness.to_string())),
                    ("streamed", Json::Bool(r.streamed)),
                    ("occupancy", json::num(r.occupancy)),
                    ("msgs_per_sec", json::num(r.msgs_per_sec)),
                    ("mean_staleness", json::num(r.mean_staleness)),
                    ("staleness_max", json::num(r.staleness_max as f64)),
                    ("grads_dropped", json::num(r.grads_dropped as f64)),
                    ("instances", json::num(r.instances as f64)),
                    ("virtual_s", json::num(r.virtual_s)),
                ])
            })),
        ),
    ]);
    let path =
        std::env::var("AMP_BENCH_OUT").unwrap_or_else(|_| "BENCH_control_plane.json".to_string());
    std::fs::write(&path, out.to_string())?;
    println!("written to {path}");
    Ok(())
}
