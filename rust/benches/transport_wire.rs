//! §Perf micro-benchmark for the transport wire format (DESIGN.md §12):
//! encode/decode latency of a `Deliver` frame carrying a realistic MLP
//! activation (64×256 f32) and of a bare control envelope, plus the
//! pooled-decode ratio. The encode path must stay a straight memcpy out
//! of the tensor's Arc storage and the decode path must draw its buffers
//! from the size-class pool — if either regresses, ns/frame and the
//! hit/miss ratio move long before a distributed run feels it.
//!
//!   cargo bench --bench transport_wire

use std::time::Instant;

use ampnet::ir::{Message, MsgState};
use ampnet::tensor::{pool, Tensor};
use ampnet::transport::wire::{decode_frame, encode_frame};
use ampnet::transport::Frame;
use ampnet::util::Pcg32;
use anyhow::Result;

const ITERS: usize = 2_000;

fn deliver_frame() -> Frame {
    let mut rng = Pcg32::seeded(7);
    let payload = vec![
        Tensor::new(vec![64, 256], rng.normal_vec(64 * 256, 0.3)),
        Tensor::new(vec![256], rng.normal_vec(256, 0.3)),
    ];
    Frame::Deliver { node: 3, port: 0, msg: Message::fwd(MsgState::for_instance(1), payload) }
}

fn bench(name: &str, frame: &Frame) -> Result<()> {
    let mut buf = Vec::new();
    encode_frame(frame, &mut buf);
    let bytes = buf.len();

    // encode: reuse one scratch buffer, like StreamTransport::send does
    let t0 = Instant::now();
    for _ in 0..ITERS {
        buf.clear();
        encode_frame(frame, &mut buf);
    }
    let enc = t0.elapsed().as_secs_f64() / ITERS as f64;

    // decode: pooled tensor buffers, one thread (the pool is thread-local)
    pool::clear();
    let t0 = Instant::now();
    for _ in 0..ITERS {
        let (decoded, used) = decode_frame(&buf).map_err(anyhow::Error::from)?;
        anyhow::ensure!(used == bytes, "partial decode");
        drop(decoded); // returns payload buffers to the pool
    }
    let dec = t0.elapsed().as_secs_f64() / ITERS as f64;
    let ps = pool::stats();

    println!(
        "{name:<18} {bytes:>8} B  encode {:>8.0} ns ({:>7.2} GB/s)  decode {:>8.0} ns ({:>7.2} GB/s)  pool {} hits / {} misses",
        enc * 1e9,
        bytes as f64 / enc / 1e9,
        dec * 1e9,
        bytes as f64 / dec / 1e9,
        ps.hits,
        ps.misses,
    );
    Ok(())
}

fn main() -> Result<()> {
    ampnet::util::logging::init();
    println!("== transport wire format: frame encode/decode ==");
    bench("deliver(64x256)", &deliver_frame())?;
    bench("heartbeat", &Frame::Heartbeat { backlog: 42 })?;

    // Regression guard, mirroring the micro_ops pool check: decoding a
    // tensor-bearing frame must reuse pooled buffers after warm-up.
    pool::clear();
    let mut buf = Vec::new();
    encode_frame(&deliver_frame(), &mut buf);
    for _ in 0..64 {
        let (decoded, _) = decode_frame(&buf).map_err(anyhow::Error::from)?;
        drop(decoded);
    }
    let ps = pool::stats();
    anyhow::ensure!(
        ps.hits > ps.misses,
        "pooled decode regression: {} hits vs {} misses — the decoder is \
         allocating fresh buffers instead of drawing from the pool",
        ps.hits,
        ps.misses
    );
    println!("pooled decode path OK ({} hits / {} misses)", ps.hits, ps.misses);
    Ok(())
}
