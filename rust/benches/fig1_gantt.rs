//! Figure 1: Gantt charts of the three execution regimes on the MLP
//! pipeline — (a) synchronous single-instance, (b) full pipeline with
//! infrequent updates (large min_update_frequency), (c) AMP. Emits one
//! trace CSV per regime (worker, start, end, fwd/bwd, instance) and
//! prints per-regime utilization + update counts.

use ampnet::data::{MnistLike, Split};
use ampnet::launcher::{args_from, backend_spec};
use ampnet::models::{mlp, ModelCfg};
use ampnet::scheduler::{EngineKind, EpochKind};
use ampnet::train::report::write_csv;
use anyhow::Result;

fn run(tag: &str, mak: usize, muf: usize) -> Result<()> {
    let args = args_from("");
    let mut mcfg = ModelCfg::default();
    mcfg.muf = muf;
    let data = MnistLike::new(0, 1600, 200, 100);
    let model = mlp::build(&mcfg, data, 4)?;
    let mut engine =
        ampnet::scheduler::build_engine(EngineKind::Sim, model.graph, backend_spec(&args)?, true)?;
    // warmup epoch (XLA compilation) then the traced epoch
    for _ in 0..2 {
        let pumps: Vec<_> = (0..16).map(|i| model.pumper.pump(Split::Train, i)).collect();
        let s = engine.run_epoch(pumps, mak, EpochKind::Train)?;
        if s.trace.is_empty() {
            continue;
        }
        let t0 = s.trace.iter().map(|t| t.start).fold(f64::MAX, f64::min);
        let rows: Vec<Vec<f64>> = s
            .trace
            .iter()
            .map(|t| {
                vec![
                    t.worker as f64,
                    (t.start - t0) * 1e3,
                    (t.end - t0) * 1e3,
                    f64::from(u8::from(t.backward)),
                    t.instance as f64,
                    t.node as f64,
                ]
            })
            .collect();
        write_csv(
            &format!("results/fig1_gantt_{tag}.csv"),
            "worker,start_ms,end_ms,backward,instance,node",
            &rows,
        )?;
        println!(
            "{tag:<22} mak={mak:<3} muf={muf:<6} utilization={:.2}  updates={:<4} span={:.1}ms",
            s.utilization(),
            s.updates,
            s.virtual_seconds * 1e3
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    ampnet::util::logging::init();
    println!("== Figure 1: execution regimes on the 4-node MLP pipeline ==");
    run("a_synchronous", 1, 100)?; // one instance in flight
    run("b_full_pipeline", 8, 100_000)?; // pipeline full, updates rare
    run("c_amp", 8, 100)?; // pipeline full, frequent async updates
    println!("traces in results/fig1_gantt_*.csv");
    Ok(())
}
