//! Table 1: time-to-target-accuracy — AMP at the paper's max_active_keys
//! settings (plus replicas for the RNN) against the synchronous TF-style
//! baseline. Prints the same row layout as the paper: time (s), epochs,
//! inst/s, with the speedup of each async row over its mak=1 row.
//!
//! Absolute numbers depend on AMP_SCALE / AMP_EPOCHS (defaults are small
//! so `cargo bench` completes on CI; set AMP_SCALE=1 for paper-sized
//! datasets). The reproduction target is the *shape*: async > sync,
//! replicas ~linear, AMP >> dense baseline on QM9 (see EXPERIMENTS.md).

use ampnet::data::{MnistLike, Qm9Gen, SentiTreeGen};
use ampnet::launcher::{args_from, backend_spec, build_model, scaled};
use ampnet::train::baseline::{BaselineCfg, SyncBaseline};
use ampnet::train::report::write_csv;
use ampnet::train::{AmpTrainer, RunReport, TargetMetric, TrainCfg};
use anyhow::Result;

fn epochs() -> usize {
    std::env::var("AMP_EPOCHS").ok().and_then(|v| v.parse().ok()).unwrap_or(3)
}

fn amp_row(model: &str, extra: &str, mak: usize) -> Result<RunReport> {
    let args = args_from(&format!("--model {model} {extra}"));
    let (m, target) = build_model(model, &args, 16)?;
    let mut cfg = TrainCfg::new(backend_spec(&args)?, mak, epochs(), target);
    cfg.early_stop = true;
    Ok(AmpTrainer::run(m, &cfg)?.0)
}

fn print_row(tag: &str, mak: usize, r: &RunReport, base_time: &mut Option<f64>, rows: &mut Vec<Vec<f64>>) {
    let time = r.time_to_target.unwrap_or_else(|| {
        r.epochs.last().map(|e| e.cum_train_seconds).unwrap_or(0.0)
    });
    let reached = r.time_to_target.is_some();
    let b = base_time.get_or_insert(time);
    println!(
        "{tag:<28} mak={mak:<3} time={time:>8.2}s{} ({:>4.1}x)  epochs={:<3} inst/s={:>9.1}",
        if reached { "" } else { "*" },
        *b / time,
        r.epochs_to_target.unwrap_or(r.epochs.len()),
        r.train_throughput
    );
    rows.push(vec![mak as f64, time, r.epochs_to_target.unwrap_or(0) as f64, r.train_throughput]);
}

fn main() -> Result<()> {
    ampnet::util::logging::init();
    if std::env::var("AMP_SCALE").is_err() {
        std::env::set_var("AMP_SCALE", "0.005"); // keep `cargo bench` bounded on CI
    }
    println!("== Table 1: time to convergence (scaled; * = target not yet reached) ==");
    let mut csv: Vec<(String, Vec<Vec<f64>>)> = Vec::new();

    // --- MNIST MLP: mak 1 vs 4; TF baseline ---------------------------------
    let mut rows = Vec::new();
    let mut base = None;
    for mak in [1usize, 4] {
        let r = amp_row("mlp", "", mak)?;
        print_row("MNIST (97%) AMP", mak, &r, &mut base, &mut rows);
    }
    {
        let args = args_from("");
        let cfg = BaselineCfg {
            backend: backend_spec(&args)?,
            max_epochs: epochs(),
            target: TargetMetric::Accuracy(0.97),
            lr: 0.1,
            seed: 42,
            max_train_instances: None,
            max_valid_instances: None,
        };
        let r = SyncBaseline::mlp(&cfg, MnistLike::new(42, scaled(60_000), scaled(10_000).max(500), 100))?;
        print_row("MNIST (97%) TF-sync", 0, &r, &mut base, &mut rows);
    }
    csv.push(("mnist".into(), rows));

    // --- List reduction RNN: mak sweep + replicas ----------------------------
    let mut rows = Vec::new();
    let mut base = None;
    for (mak, replicas) in [(1usize, 1usize), (4, 1), (16, 1), (4, 2), (8, 4)] {
        let r = amp_row("rnn", &format!("--replicas {replicas}"), mak)?;
        print_row(&format!("ListRed (97%) AMP r{replicas}"), mak, &r, &mut base, &mut rows);
    }
    csv.push(("listred".into(), rows));

    // --- Sentiment tree: mak 1/4/16 + TF-Fold baseline -----------------------
    let mut rows = Vec::new();
    let mut base = None;
    for mak in [1usize, 4, 16] {
        let r = amp_row("tree", "", mak)?;
        print_row("Sentiment (82%) AMP", mak, &r, &mut base, &mut rows);
    }
    {
        let args = args_from("");
        let cfg = BaselineCfg {
            backend: backend_spec(&args)?,
            max_epochs: epochs(),
            target: TargetMetric::Accuracy(0.82),
            lr: 0.003,
            seed: 42,
            max_train_instances: None,
            max_valid_instances: None,
        };
        let r = SyncBaseline::tree(&cfg, SentiTreeGen::new(42, scaled(8544), scaled(1101).max(64)), 20)?;
        print_row("Sentiment (82%) TF-Fold", 0, &r, &mut base, &mut rows);
    }
    csv.push(("sentiment".into(), rows));

    // --- bAbI 15: mak 1/16 ----------------------------------------------------
    let mut rows = Vec::new();
    let mut base = None;
    for mak in [1usize, 16] {
        let r = amp_row("babi", "", mak)?;
        print_row("bAbI15 (100%) AMP", mak, &r, &mut base, &mut rows);
    }
    csv.push(("babi".into(), rows));

    // --- QM9: mak 4/16 + dense TF baseline -----------------------------------
    let mut rows = Vec::new();
    let mut base = None;
    for mak in [4usize, 16] {
        let r = amp_row("qm9", "", mak)?;
        print_row("QM9 (4.6) AMP-sparse", mak, &r, &mut base, &mut rows);
    }
    {
        let args = args_from("");
        let cfg = BaselineCfg {
            backend: backend_spec(&args)?,
            max_epochs: 1,
            target: TargetMetric::MaeRatio { ratio: 4.6, unit: 0.1 },
            lr: 0.003,
            seed: 42,
            max_train_instances: Some(scaled(117_000).min(30)),
            max_valid_instances: Some(8),
        };
        let r = SyncBaseline::ggsnn_dense_qm9(&cfg, Qm9Gen::new(42, scaled(117_000).max(30), 8))?;
        print_row("QM9 (4.6) TF-dense", 0, &r, &mut base, &mut rows);
    }
    csv.push(("qm9".into(), rows));

    for (name, rows) in csv {
        write_csv(
            &format!("results/table1_{name}.csv"),
            "mak,time_to_target_s,epochs_to_target,train_inst_s",
            &rows,
        )?;
    }
    println!("rows written to results/table1_*.csv");
    Ok(())
}
