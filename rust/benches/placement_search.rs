//! Placement-search bench (DESIGN.md §14): calibrate a measured cost
//! profile for the GGSNN/qm9 graph, run the annealing tuner, and report
//! the simulated makespan of three placements under that one profile —
//! the paper's hand-pinned layout, cost-aware LPT over measured costs,
//! and the tuned winner — plus the search throughput.
//!
//! Emits `BENCH_placement_search.json` (override with `AMP_BENCH_OUT`)
//! so the tuner's win over LPT is tracked across PRs; the strict
//! beats-LPT acceptance assert lives in `tests/placement_search.rs`.

use ampnet::data::Split;
use ampnet::ir::PumpSet;
use ampnet::launcher::{args_from, build_model};
use ampnet::placement::{calibrate, search, ProfiledCost, SearchCfg};
use ampnet::runtime::BackendSpec;
use ampnet::scheduler::{Engine, EpochKind, SimEngine};
use ampnet::util::json::{self, Json};
use anyhow::Result;

const WORKERS: usize = 16;
const CALIB_PUMPS: usize = 24;
const SEARCH_PUMPS: usize = 8;
const MAK: usize = 4;
const ITERS: usize = 600;
const SEED: u64 = 7;

fn main() -> Result<()> {
    ampnet::util::logging::init();
    std::env::set_var("AMP_SCALE", "0.002");
    println!("== Placement search: pinned vs cost-LPT vs tuned (qm9, {WORKERS} workers) ==");

    // The paper's hand-pinned layout, kept aside as the baseline curve.
    let (baseline, _t) = build_model("qm9", &args_from("--seed 42"), WORKERS)?;
    let pinned_asg: Vec<usize> = baseline.graph.nodes.iter().map(|s| s.worker).collect();

    let (model, _t) = build_model("qm9", &args_from("--seed 42"), WORKERS)?;
    let pumper = model.pumper;
    let calib: Vec<PumpSet> =
        (0..CALIB_PUMPS).map(|i| pumper.pump(Split::Train, i)).collect();
    let mut eng = SimEngine::new(model.graph, BackendSpec::native(), true)?;
    let t0 = std::time::Instant::now();
    let profile = calibrate(&mut eng, calib, MAK, "qm9")?;
    let calib_s = t0.elapsed().as_secs_f64();

    let pumps: Vec<PumpSet> =
        (0..SEARCH_PUMPS).map(|i| pumper.pump(Split::Train, i)).collect();
    let cfg = SearchCfg { seed: SEED, max_iters: ITERS, budget_s: None, relay: false };
    let res = search(&mut eng, &profile, &pumps, MAK, &cfg)?;
    assert!(res.makespan <= res.lpt_makespan, "tuned worse than its LPT seed");

    // Score the paper's pinned layout under the same cost model and
    // workload so all three makespans are directly comparable.
    eng.set_cost_model(Some(Box::new(ProfiledCost::new(&profile, eng.graph()))));
    eng.graph_mut().set_workers(&pinned_asg);
    let pinned_makespan =
        eng.run_epoch(pumps.clone(), MAK, EpochKind::Train)?.virtual_seconds;
    eng.set_cost_model(None);

    let vs_lpt = 1.0 - res.makespan / res.lpt_makespan;
    let vs_pinned = 1.0 - res.makespan / pinned_makespan;
    let iters_per_sec = res.iters as f64 / res.elapsed_s.max(1e-9);
    println!("calibration: {CALIB_PUMPS} pumps in {calib_s:.2}s ({} nodes)", profile.nodes.len());
    println!("pinned   makespan {pinned_makespan:.6}s  (paper layout)");
    println!("cost-LPT makespan {:.6}s", res.lpt_makespan);
    println!(
        "tuned    makespan {:.6}s  ({:.1}% vs LPT, {:.1}% vs pinned; {} iters, {} accepted, {:.0} iters/s)",
        res.makespan,
        100.0 * vs_lpt,
        100.0 * vs_pinned,
        res.iters,
        res.accepted,
        iters_per_sec,
    );

    let out = json::obj(vec![
        ("bench", json::s("placement_search")),
        ("model", json::s("qm9")),
        ("workers", json::num(WORKERS as f64)),
        ("mak", json::num(MAK as f64)),
        ("calib_pumps", json::num(CALIB_PUMPS as f64)),
        ("search_pumps", json::num(SEARCH_PUMPS as f64)),
        ("seed", json::num(SEED as f64)),
        ("calibration_s", json::num(calib_s)),
        ("pinned_makespan_s", json::num(pinned_makespan)),
        ("lpt_makespan_s", json::num(res.lpt_makespan)),
        ("tuned_makespan_s", json::num(res.makespan)),
        ("improvement_vs_lpt", json::num(vs_lpt)),
        ("improvement_vs_pinned", json::num(vs_pinned)),
        ("iters", json::num(res.iters as f64)),
        ("accepted", json::num(res.accepted as f64)),
        ("iters_per_sec", json::num(iters_per_sec)),
        ("elapsed_s", json::num(res.elapsed_s)),
        ("tuned_beats_lpt", Json::Bool(res.makespan < res.lpt_makespan)),
    ]);
    let path = std::env::var("AMP_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_placement_search.json".to_string());
    std::fs::write(&path, out.to_string())?;
    println!("written to {path}");
    Ok(())
}
