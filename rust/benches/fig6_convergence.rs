//! Figure 6 (a–f): validation accuracy vs wall-clock (virtual) time and
//! vs epochs, per dataset and max_active_keys. One CSV per dataset with
//! one row per (mak, epoch).

use ampnet::launcher::{args_from, backend_spec, build_model};
use ampnet::train::report::write_csv;
use ampnet::train::{AmpTrainer, TrainCfg};
use anyhow::Result;

fn main() -> Result<()> {
    ampnet::util::logging::init();
    if std::env::var("AMP_SCALE").is_err() {
        std::env::set_var("AMP_SCALE", "0.005"); // keep `cargo bench` bounded on CI
    }
    let epochs = std::env::var("AMP_EPOCHS").ok().and_then(|v| v.parse().ok()).unwrap_or(3);
    let datasets: &[(&str, &[usize])] = &[
        ("mlp", &[1, 4, 8]),
        ("rnn", &[1, 4, 16]),
        ("tree", &[1, 4, 16]),
        ("babi", &[1, 16]),
        ("qm9", &[4, 16]),
    ];
    for (model, maks) in datasets {
        let mut rows = Vec::new();
        for &mak in maks.iter() {
            let args = args_from(&format!("--model {model}"));
            let (m, target) = build_model(model, &args, 16)?;
            let mut cfg = TrainCfg::new(backend_spec(&args)?, mak, epochs, target);
            cfg.early_stop = false;
            let (r, _) = AmpTrainer::run(m, &cfg)?;
            for e in &r.epochs {
                println!(
                    "{model:<5} mak={mak:<3} epoch={:<2} t={:>7.2}s acc={:.4} mae={:.4}",
                    e.epoch, e.cum_train_seconds, e.valid_accuracy, e.valid_mae
                );
                rows.push(vec![
                    mak as f64,
                    e.epoch as f64,
                    e.cum_train_seconds,
                    e.valid_accuracy,
                    e.valid_mae,
                    e.train.mean_loss(),
                ]);
            }
        }
        write_csv(
            &format!("results/fig6_{model}.csv"),
            "mak,epoch,cum_train_s,valid_acc,valid_mae,train_loss",
            &rows,
        )?;
    }
    println!("curves written to results/fig6_*.csv");
    Ok(())
}
