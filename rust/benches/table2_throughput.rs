//! Table 2: training and validation throughput (inst/s) per dataset and
//! max_active_keys. One train + one eval epoch per configuration (the
//! first epoch carries XLA compile warmup, so we run two and report the
//! second).

use ampnet::data::Split;
use ampnet::launcher::{args_from, backend_spec, build_model};
use ampnet::scheduler::{EngineKind, EpochKind};
use ampnet::train::report::write_csv;
use anyhow::Result;

fn measure(model: &str, extra: &str, mak: usize) -> Result<(f64, f64)> {
    let args = args_from(&format!("--model {model} {extra}"));
    let (m, _t) = build_model(model, &args, 16)?;
    let mut engine =
        ampnet::scheduler::build_engine(EngineKind::Sim, m.graph, backend_spec(&args)?, false)?;
    let pumper = m.pumper;
    let nt = pumper.n(Split::Train).min(60);
    let nv = pumper.n(Split::Valid).min(60);
    let mut train_tput = 0.0;
    for _ in 0..2 {
        let pumps: Vec<_> = (0..nt).map(|i| pumper.pump(Split::Train, i)).collect();
        let s = engine.run_epoch(pumps, mak, EpochKind::Train)?;
        train_tput = s.throughput();
        ampnet::scheduler::sync_replicas(engine.as_mut(), &m.replica_groups)?;
    }
    let pumps: Vec<_> = (0..nv).map(|i| pumper.pump(Split::Valid, i)).collect();
    let s = engine.run_epoch(pumps, mak, EpochKind::Eval)?;
    Ok((train_tput, s.throughput()))
}

fn main() -> Result<()> {
    ampnet::util::logging::init();
    if std::env::var("AMP_SCALE").is_err() {
        std::env::set_var("AMP_SCALE", "0.005"); // keep `cargo bench` bounded on CI
    }
    println!("== Table 2: train/valid throughput (virtual inst/s, 16 workers) ==");
    let mut rows = Vec::new();
    let configs: &[(&str, &str, usize)] = &[
        ("mlp", "", 1),
        ("mlp", "", 4),
        ("rnn", "", 1),
        ("rnn", "", 4),
        ("rnn", "", 16),
        ("rnn", "--replicas 2", 4),
        ("rnn", "--replicas 4", 8),
        ("tree", "", 1),
        ("tree", "", 4),
        ("tree", "", 16),
        ("babi", "", 1),
        ("babi", "", 16),
        ("qm9", "", 4),
        ("qm9", "", 16),
    ];
    for (i, (model, extra, mak)) in configs.iter().enumerate() {
        let (tr, va) = measure(model, extra, *mak)?;
        println!("{model:<6}{extra:<14} mak={mak:<3} train={tr:>9.1} inst/s  valid={va:>9.1} inst/s");
        rows.push(vec![i as f64, *mak as f64, tr, va]);
    }
    write_csv("results/table2_throughput.csv", "config,mak,train_inst_s,valid_inst_s", &rows)?;
    println!("written to results/table2_throughput.csv");
    Ok(())
}
