//! Appendix C: analytical FPGA-network throughput for the QM9 GGSNN.
//! Prints the paper's headline configuration plus sweeps over H and E
//! (the GRU-bound vs edge-bound crossover).

use ampnet::analysis::FpgaModel;

fn main() {
    println!("== Appendix C: 1-TFLOPS device network, GGSNN/QM9 ==");
    let m = FpgaModel::qm9_paper();
    println!(
        "paper config (H=200, N=E=30, C=4, T=4): {:.0} graphs/s, {:.2} Gb/s, {} devices, {:.2} MB/device",
        m.throughput(),
        m.bandwidth_bits() / 1e9,
        m.devices_needed(),
        m.per_device_memory() as f64 / 1e6
    );
    println!("paper reports ~6.5e3 graphs/s and 1.2 Gb/s.\n");
    println!("H sweep (N=E=30):");
    for h in [50, 100, 200, 400] {
        let mut m = FpgaModel::qm9_paper();
        m.h = h;
        println!(
            "  H={h:<4} {:>10.0} graphs/s {:>8.2} Gb/s",
            m.throughput(),
            m.bandwidth_bits() / 1e9
        );
    }
    println!("E sweep (H=200, N=30): crossover to edge-bound at E = 2NC = 240");
    for e in [30, 120, 240, 480, 960] {
        let mut m = FpgaModel::qm9_paper();
        m.e = e;
        println!(
            "  E={e:<4} {:>10.0} graphs/s {:>8.2} Gb/s",
            m.throughput(),
            m.bandwidth_bits() / 1e9
        );
    }
}
