//! Serving-lane latency bench (DESIGN.md §15): p50/p99 inference
//! latency vs training occupancy at several scripted request rates, on
//! the sim engine (deterministic virtual-time arrivals, so the shed set
//! and every latency are reproducible across runs).
//!
//! Emits `BENCH_serve_latency.json` (override with `AMP_BENCH_OUT`) so
//! CI tracks the serving latency trajectory across PRs.

use ampnet::data::MnistLike;
use ampnet::models::{mlp, ModelCfg};
use ampnet::runtime::BackendSpec;
use ampnet::train::{AmpTrainer, ServeCfg, TargetMetric, TrainCfg};
use ampnet::util::json;
use anyhow::Result;

const MAK: usize = 4;
const EPOCHS: usize = 2;
const WORKERS: usize = 4;

struct Row {
    rate: f64,
    submitted: usize,
    completed: usize,
    shed: usize,
    p50: f64,
    p99: f64,
    mean: f64,
    train_occupancy: f64,
    infer_occupancy: f64,
    snapshot_epochs: u64,
}

fn run(rate: f64) -> Result<Row> {
    let mut mcfg = ModelCfg::default();
    mcfg.lr = 0.05;
    mcfg.muf = 100;
    // 1000 validation samples = 10 batched instances = 10 scripted
    // requests per rate (the inline script is one request per sample).
    let model = mlp::build(&mcfg, MnistLike::new(0, 500, 1000, 100), WORKERS)?;
    let mut cfg = TrainCfg::new(
        BackendSpec::native(),
        MAK,
        EPOCHS,
        TargetMetric::Accuracy(0.99),
    );
    cfg.early_stop = false;
    cfg.serve = Some(ServeCfg::Inline { rate, deadline_ms: 0 });
    let (report, mut engine) = AmpTrainer::run(model, &cfg)?;
    anyhow::ensure!(engine.cached_keys()? == 0, "leaked keys");
    let sv = report.serve.expect("serve section");
    let train_occupancy = report
        .epochs
        .iter()
        .map(|e| e.train.mean_occupancy())
        .sum::<f64>()
        / report.epochs.len().max(1) as f64;
    Ok(Row {
        rate,
        submitted: sv.submitted,
        completed: sv.completed,
        shed: sv.total_shed(),
        p50: sv.p50_latency,
        p99: sv.p99_latency,
        mean: sv.mean_latency,
        train_occupancy,
        infer_occupancy: sv.infer_occupancy,
        snapshot_epochs: sv.snapshot_epochs,
    })
}

fn main() -> Result<()> {
    ampnet::util::logging::init();
    println!("== Serve latency: p50/p99 vs train occupancy per request rate ==");
    println!("   (mlp, native backend, sim engine, mak {MAK}, {EPOCHS} epochs, scripted arrivals)");
    let mut rows = Vec::new();
    for rate in [50.0, 200.0, 800.0] {
        let r = run(rate)?;
        println!(
            "rate={:>5.0}/s submitted={:>3} completed={:>3} shed={} p50={:.4}s p99={:.4}s \
             train_occ={:.2} infer_occ={:.2} snapshots={}",
            r.rate,
            r.submitted,
            r.completed,
            r.shed,
            r.p50,
            r.p99,
            r.train_occupancy,
            r.infer_occupancy,
            r.snapshot_epochs,
        );
        rows.push(r);
    }

    // Machine-checkable properties: accounting is exact at every rate
    // (every request answered or typed-shed) and completed requests
    // produced a real latency signal.
    assert!(rows.iter().all(|r| r.completed + r.shed == r.submitted));
    assert!(rows.iter().all(|r| r.completed > 0 && r.p50 > 0.0 && r.p99 >= r.p50));
    assert!(rows.iter().all(|r| r.snapshot_epochs >= 1));

    let out = json::obj(vec![
        ("bench", json::s("serve_latency")),
        ("model", json::s("mlp-mnist")),
        ("mak", json::num(MAK as f64)),
        ("epochs", json::num(EPOCHS as f64)),
        ("workers", json::num(WORKERS as f64)),
        (
            "rates",
            json::arr(rows.iter().map(|r| {
                json::obj(vec![
                    ("rate", json::num(r.rate)),
                    ("submitted", json::num(r.submitted as f64)),
                    ("completed", json::num(r.completed as f64)),
                    ("shed", json::num(r.shed as f64)),
                    ("p50_latency_s", json::num(r.p50)),
                    ("p99_latency_s", json::num(r.p99)),
                    ("mean_latency_s", json::num(r.mean)),
                    ("train_occupancy", json::num(r.train_occupancy)),
                    ("infer_occupancy", json::num(r.infer_occupancy)),
                    ("snapshot_epochs", json::num(r.snapshot_epochs as f64)),
                ])
            })),
        ),
    ]);
    let path =
        std::env::var("AMP_BENCH_OUT").unwrap_or_else(|_| "BENCH_serve_latency.json".to_string());
    std::fs::write(&path, out.to_string())?;
    println!("written to {path}");
    Ok(())
}
