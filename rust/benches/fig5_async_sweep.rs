//! Figure 5: convergence time/epochs as a function of the asynchrony
//! hyperparameters min_update_frequency x max_active_keys, on the
//! replicated RNN. Writes the full grid to results/fig5_sweep.csv.
//!
//! Scaled defaults (grid 3x4, 96%-target on a reduced dataset); the shape
//! to reproduce: muf has an interior optimum, mak rises then saturates
//! near the number of heavy nodes.

use ampnet::data::ListRedGen;
use ampnet::launcher::{backend_spec, args_from, scaled};
use ampnet::models::{rnn, ModelCfg};
use ampnet::train::report::write_csv;
use ampnet::train::{AmpTrainer, TargetMetric, TrainCfg};
use anyhow::Result;

fn main() -> Result<()> {
    ampnet::util::logging::init();
    if std::env::var("AMP_SCALE").is_err() {
        std::env::set_var("AMP_SCALE", "0.02"); // keep `cargo bench` bounded on CI
    }
    let args = args_from("");
    let replicas = 4usize;
    let epochs = std::env::var("AMP_EPOCHS").ok().and_then(|v| v.parse().ok()).unwrap_or(4);
    let mufs = [10usize, 100, 800];
    let maks = [1usize, 4, 8, 16];
    println!("== Figure 5: muf x mak sweep on the {replicas}-replica RNN ==");
    let mut rows = Vec::new();
    for &muf in &mufs {
        for &mak in &maks {
            let mut mcfg = ModelCfg::default();
            mcfg.muf = muf;
            mcfg.lr = 0.5;
            let data = ListRedGen::new(42, scaled(100_000), scaled(10_000).max(500), 100);
            let model = rnn::build(&mcfg, data, 16, replicas)?;
            let mut cfg = TrainCfg::new(
                backend_spec(&args)?,
                mak,
                epochs,
                TargetMetric::Accuracy(0.96),
            );
            cfg.early_stop = true;
            let (r, _) = AmpTrainer::run(model, &cfg)?;
            let time = r
                .time_to_target
                .unwrap_or_else(|| r.epochs.last().map(|e| e.cum_train_seconds).unwrap_or(0.0));
            let eps = r.epochs_to_target.unwrap_or(r.epochs.len());
            let acc = r.epochs.last().map(|e| e.valid_accuracy).unwrap_or(0.0);
            let reached = r.time_to_target.is_some();
            println!(
                "muf={muf:<5} mak={mak:<3} time={time:>7.2}s{} epochs={eps:<3} final_acc={acc:.3} inst/s={:.0}",
                if reached { " " } else { "*" },
                r.train_throughput
            );
            rows.push(vec![
                muf as f64,
                mak as f64,
                time,
                eps as f64,
                acc,
                r.train_throughput,
                f64::from(u8::from(reached)),
            ]);
        }
    }
    write_csv(
        "results/fig5_sweep.csv",
        "muf,mak,time_s,epochs,final_acc,train_inst_s,reached",
        &rows,
    )?;
    println!("grid written to results/fig5_sweep.csv");
    Ok(())
}
