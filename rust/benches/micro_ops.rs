//! §Perf micro-benchmarks: per-op execute latency through each backend
//! and artifact flavor, plus the scheduler message-path overhead. These
//! are the numbers the optimization log in EXPERIMENTS.md §Perf tracks.

use std::sync::Arc;
use std::time::Instant;

use ampnet::runtime::{Backend, BackendSpec, Manifest, NativeBackend, XlaBackend};
use ampnet::tensor::Tensor;
use ampnet::util::Pcg32;
use anyhow::Result;

fn bench_op(be: &mut dyn Backend, name: &str, manifest: &Manifest, iters: usize) -> Result<f64> {
    let spec = manifest.get(name)?;
    let mut rng = Pcg32::seeded(1);
    let ins: Vec<Tensor> = spec
        .inputs
        .iter()
        .map(|s| Tensor::new(s.clone(), rng.normal_vec(s.iter().product(), 0.3)))
        .collect();
    be.execute(name, &ins)?; // warmup / compile
    let t0 = Instant::now();
    for _ in 0..iters {
        be.execute(name, &ins)?;
    }
    Ok(t0.elapsed().as_secs_f64() / iters as f64)
}

fn main() -> Result<()> {
    ampnet::util::logging::init();
    let manifest = Arc::new(Manifest::load_default()?);
    let mut xla = XlaBackend::new(manifest.clone())?;
    let mut native = NativeBackend::new();
    let ops = [
        ("linear_relu_fwd__b100_i784_o784__xla", 30),
        ("linear_relu_fwd__b100_i784_o784__pallas", 10),
        ("linear_relu_bwd__b100_i784_o784__xla", 20),
        ("linear_relu_fwd__b100_i256_o128__xla", 50),
        ("lstm_leaf_fwd__b16_h128_i128__xla", 50),
        ("lstm_branch_fwd__b1_h128__xla", 50),
        ("gru_fwd__b32_h100_i100__xla", 50),
        ("gru_fwd__b32_h100_i100__pallas", 10),
        ("gru_bwd__b32_h100_i100__xla", 30),
        ("linear_fwd__b16_i100_o100__xla", 100),
        ("xent_fwd__b100_c10__xla", 100),
        ("matmul_fwd__b1_i3200_o3200__xla", 5),
    ];
    println!("== micro: per-op execute latency (lower is better) ==");
    println!("{:<46} {:>12} {:>12}", "artifact", "xla (us)", "native (us)");
    for (name, iters) in ops {
        let x = bench_op(&mut xla, name, &manifest, iters)?;
        let n = bench_op(&mut native, name, &manifest, iters.min(10))?;
        println!("{name:<46} {:>12.1} {:>12.1}", x * 1e6, n * 1e6);
    }

    // message-path overhead: route a tiny op through the sim engine and
    // compare with raw execute.
    println!("\n== scheduler overhead (sim engine, per message) ==");
    use ampnet::ir::nodes::{linear_params, LossKind, LossNode, PptConfig, PptNode};
    use ampnet::ir::{Message, MsgState, NetBuilder, NodeSpec, Pinned, PumpSet};
    use ampnet::optim::Optimizer;
    use ampnet::scheduler::{Engine, EpochKind};
    use ampnet::tensor::ops as tops;
    let mut rng = Pcg32::seeded(2);
    let mut g = NetBuilder::new();
    let lin = g.add(
        NodeSpec::new("lin").pin(0),
        Box::new(PptNode::new(
            "lin",
            PptConfig::simple(
                "linear",
                ampnet::runtime::KernelFlavor::Xla,
                &[("i", 128), ("o", 5)],
                vec![64],
            ),
            linear_params(&mut rng, 128, 5),
            Optimizer::sgd(0.01),
            1_000_000,
        )),
    );
    let loss = g.add(
        NodeSpec::new("loss").inputs(2).outputs(0).pin(1),
        Box::new(LossNode::new("loss", LossKind::Xent { classes: 5 }, vec![64])),
    );
    g.wire(lin.out(0), loss.input(0));
    g.controller_input(lin.input(0));
    g.controller_input(loss.input(1));
    let mut eng = ampnet::scheduler::SimEngine::new(
        g.build(2, &Pinned)?.graph,
        BackendSpec::new(ampnet::runtime::BackendKind::Xla, manifest.clone()),
        false,
    )?;
    let n_inst = 200usize;
    let pumps: Vec<PumpSet> = (0..n_inst)
        .map(|i| {
            let s = MsgState::for_instance(i as u64);
            let mut p = PumpSet::new();
            let mut rng = Pcg32::seeded(i as u64);
            p.push(lin.id(), 0, Message::fwd(s, vec![Tensor::new(vec![64, 128], rng.normal_vec(64 * 128, 0.3))]));
            let labels: Vec<usize> = (0..64).map(|k| (i + k) % 5).collect();
            p.push(loss.id(), 1, Message::fwd(s, vec![tops::one_hot(&labels, 5)]));
            p
        })
        .collect();
    let t0 = Instant::now();
    let stats = eng.run_epoch(pumps, 8, EpochKind::Train)?;
    let wall = t0.elapsed().as_secs_f64();
    // 4 node invocations per instance (lin fwd, loss, lin bwd via loss join)
    let msgs = stats.instances * 4;
    println!(
        "{} instances, {:.1} us wall per message invocation ({:.0} inst/s 1-core wall)",
        stats.instances,
        wall / msgs as f64 * 1e6,
        stats.instances as f64 / wall
    );
    Ok(())
}
