//! §Perf micro-benchmarks: per-op execute latency through each backend
//! and artifact flavor, plus the scheduler message-path overhead. These
//! are the numbers the optimization log in EXPERIMENTS.md §Perf tracks.
//!
//! The per-op section needs the AOT artifacts (`make artifacts`) and is
//! skipped gracefully without them. The scheduler-overhead section runs
//! the native backend so it works everywhere (CI uses it as a smoke
//! check); it reports the *overhead* of the message path — engine wall
//! time per node invocation minus the raw `Backend::execute` floor for
//! the same ops — which is the quantity the zero-copy/pooled/batched
//! hot-path work optimizes.

use std::sync::Arc;
use std::time::Instant;

use ampnet::ir::nodes::{linear_params, LossKind, LossNode, PptConfig, PptNode};
use ampnet::ir::{MsgState, NetBuilder, NodeSpec, Pinned, PumpSet};
use ampnet::optim::Optimizer;
use ampnet::runtime::{Backend, BackendSpec, KernelFlavor, Manifest, NativeBackend, XlaBackend};
use ampnet::scheduler::{Engine, EpochKind};
use ampnet::tensor::{ops as tops, pool, Tensor};
use ampnet::util::Pcg32;
use anyhow::Result;

fn bench_op(be: &mut dyn Backend, name: &str, manifest: &Manifest, iters: usize) -> Result<f64> {
    let spec = manifest.get(name)?;
    let mut rng = Pcg32::seeded(1);
    let ins: Vec<Tensor> = spec
        .inputs
        .iter()
        .map(|s| Tensor::new(s.clone(), rng.normal_vec(s.iter().product(), 0.3)))
        .collect();
    be.execute(name, &ins)?; // warmup / compile
    let t0 = Instant::now();
    for _ in 0..iters {
        be.execute(name, &ins)?;
    }
    Ok(t0.elapsed().as_secs_f64() / iters as f64)
}

fn per_op_section(manifest: Arc<Manifest>) -> Result<()> {
    let mut xla = XlaBackend::new(manifest.clone())?;
    let mut native = NativeBackend::new();
    let ops = [
        ("linear_relu_fwd__b100_i784_o784__xla", 30),
        ("linear_relu_fwd__b100_i784_o784__pallas", 10),
        ("linear_relu_bwd__b100_i784_o784__xla", 20),
        ("linear_relu_fwd__b100_i256_o128__xla", 50),
        ("lstm_leaf_fwd__b16_h128_i128__xla", 50),
        ("lstm_branch_fwd__b1_h128__xla", 50),
        ("gru_fwd__b32_h100_i100__xla", 50),
        ("gru_fwd__b32_h100_i100__pallas", 10),
        ("gru_bwd__b32_h100_i100__xla", 30),
        ("linear_fwd__b16_i100_o100__xla", 100),
        ("xent_fwd__b100_c10__xla", 100),
        ("matmul_fwd__b1_i3200_o3200__xla", 5),
    ];
    println!("== micro: per-op execute latency (lower is better) ==");
    println!("{:<46} {:>12} {:>12}", "artifact", "xla (us)", "native (us)");
    for (name, iters) in ops {
        let x = bench_op(&mut xla, name, &manifest, iters)?;
        let n = bench_op(&mut native, name, &manifest, iters.min(10))?;
        println!("{name:<46} {:>12.1} {:>12.1}", x * 1e6, n * 1e6);
    }
    Ok(())
}

// Pipeline dims for the scheduler-overhead section: lin(128->5) -> xent.
const B: usize = 64;
const DIN: usize = 128;
const DOUT: usize = 5;

/// Raw `Backend::execute` floor: mean latency of the four native ops one
/// instance runs through the pipeline (lin fwd, xent fwd, xent bwd,
/// lin bwd), with argument vectors built once outside the loop.
fn raw_execute_floor(iters: usize) -> Result<f64> {
    let mut be = NativeBackend::new();
    let mut rng = Pcg32::seeded(3);
    let x = Tensor::new(vec![B, DIN], rng.normal_vec(B * DIN, 0.3));
    let mut ps = linear_params(&mut rng, DIN, DOUT);
    let bias = ps.pop().unwrap();
    let w = ps.pop().unwrap();
    let labels: Vec<usize> = (0..B).map(|k| k % DOUT).collect();
    let onehot = tops::one_hot(&labels, DOUT);
    let dy = Tensor::new(vec![B, DOUT], rng.normal_vec(B * DOUT, 0.3));
    let lin_fwd = format!("linear_fwd__b{B}_i{DIN}_o{DOUT}__xla");
    let lin_bwd = format!("linear_bwd__b{B}_i{DIN}_o{DOUT}__xla");
    let xent_fwd = format!("xent_fwd__b{B}_c{DOUT}__xla");
    let xent_bwd = format!("xent_bwd__b{B}_c{DOUT}__xla");
    let fwd_args = vec![x.clone(), w.clone(), bias.clone()];
    let logits = be.execute(&lin_fwd, &fwd_args)?.pop().unwrap();
    let loss_args = vec![logits, onehot];
    let bwd_args = vec![x, w, bias, dy];
    let t0 = Instant::now();
    for _ in 0..iters {
        be.execute(&lin_fwd, &fwd_args)?;
        be.execute(&xent_fwd, &loss_args)?;
        be.execute(&xent_bwd, &loss_args)?;
        be.execute(&lin_bwd, &bwd_args)?;
    }
    Ok(t0.elapsed().as_secs_f64() / (iters * 4) as f64)
}

/// Message-path overhead: route the same pipeline through the sim engine
/// and subtract the raw execute floor.
fn scheduler_overhead_section() -> Result<()> {
    let n_inst: usize = std::env::var("AMP_MICRO_INST")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200);
    let mut rng = Pcg32::seeded(2);
    let mut g = NetBuilder::new();
    let lin = g.add(
        NodeSpec::new("lin").pin(0),
        Box::new(PptNode::new(
            "lin",
            PptConfig::simple(
                "linear",
                KernelFlavor::Xla,
                &[("i", DIN), ("o", DOUT)],
                vec![B],
            ),
            linear_params(&mut rng, DIN, DOUT),
            Optimizer::sgd(0.01),
            1_000_000,
        )),
    );
    let loss = g.add(
        NodeSpec::new("loss").inputs(2).outputs(0).pin(1),
        Box::new(LossNode::new("loss", LossKind::Xent { classes: DOUT }, vec![B])),
    );
    g.wire(lin.out(0), loss.input(0));
    g.controller_input(lin.input(0));
    g.controller_input(loss.input(1));
    let mut eng = ampnet::scheduler::SimEngine::new(
        g.build(2, &Pinned)?.graph,
        BackendSpec::native(),
        false,
    )?;
    let pumps: Vec<PumpSet> = (0..n_inst)
        .map(|i| {
            let s = MsgState::for_instance(i as u64);
            let mut p = PumpSet::new(true);
            let mut rng = Pcg32::seeded(i as u64);
            p.push(lin.id(), 0, s, vec![Tensor::new(vec![B, DIN], rng.normal_vec(B * DIN, 0.3))]);
            let labels: Vec<usize> = (0..B).map(|k| (i + k) % DOUT).collect();
            p.push(loss.id(), 1, s, vec![tops::one_hot(&labels, DOUT)]);
            p
        })
        .collect();
    let raw = raw_execute_floor((n_inst / 2).max(10))?;
    let t0 = Instant::now();
    let stats = eng.run_epoch(pumps, 8, EpochKind::Train)?;
    let wall = t0.elapsed().as_secs_f64();
    // 4 node invocations per instance (lin fwd, loss label, loss fire,
    // lin bwd); the loss fire runs two ops, the label store runs none, so
    // the compute floor is also 4 raw ops per instance.
    let msgs = stats.instances * 4;
    let per_msg = wall / msgs as f64;
    let overhead = per_msg - raw;
    let ps = pool::stats();
    println!("\n== scheduler message-path overhead (sim engine, native backend) ==");
    println!("{} instances, {} node invocations", stats.instances, msgs);
    println!("raw execute floor:     {:>8.2} us/op", raw * 1e6);
    println!("engine wall:           {:>8.2} us/invocation", per_msg * 1e6);
    println!(
        "message-path overhead: {:>8.2} us/message  ({:.0} inst/s 1-core wall)",
        overhead * 1e6,
        stats.instances as f64 / wall
    );
    println!(
        "buffer pool: {} hits / {} misses / {} recycled",
        ps.hits, ps.misses, ps.recycled
    );
    // Regression guard (this is what makes the CI smoke-run meaningful):
    // after warm-up the pooled hot path must dominate — reintroducing a
    // per-invocation `vec![0.0; n]` or a deep payload copy flips this
    // ratio long before it shows up in flaky wall-clock numbers.
    if n_inst >= 20 {
        anyhow::ensure!(
            ps.hits > ps.misses,
            "buffer pool regression: {} hits vs {} misses — the message \
             hot path is allocating instead of reusing",
            ps.hits,
            ps.misses
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    ampnet::util::logging::init();
    match Manifest::load_default() {
        Ok(m) => per_op_section(Arc::new(m))?,
        Err(_) => {
            println!("== micro: artifacts/ not built; skipping per-op latency section ==")
        }
    }
    scheduler_overhead_section()
}
