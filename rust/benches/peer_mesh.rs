//! §Perf bench for the peer-link mesh (DESIGN.md §16): the same QM9
//! GGSNN stream over four UDS worker processes, once with cross-shard
//! `Deliver`s relayed through the head (the oracle wire topology) and
//! once over the direct worker↔worker mesh (`--peer-links on`). Reports
//! cross-shard `Deliver` frames/sec and the head's inbound `Deliver`
//! count per mode — the whole point of the mesh is driving the latter
//! to zero, so the bench self-asserts it and fails loudly if a frame
//! sneaks back onto the head FIFO.
//!
//! Emits `BENCH_peer_mesh.json` (override with `AMP_BENCH_OUT`) so the
//! relay→mesh frame budget is tracked across PRs.
//!
//!   cargo bench --bench peer_mesh

use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use ampnet::data::Split;
use ampnet::launcher::{args_from, build_model};
use ampnet::models::BuiltModel;
use ampnet::runtime::BackendSpec;
use ampnet::scheduler::{Engine, FixedMak, StreamPlan};
use ampnet::transport::{DistEngine, RecoveryOpts, RemoteSpec, TransportKind};
use ampnet::util::json;
use anyhow::Result;

const SCALE: &str = "0.001";
const WORKERS: usize = 4;
const PUMPS: usize = 24;
const MAK: usize = 4;

struct Row {
    mode: &'static str,
    /// Cross-shard `Deliver` frames carried by this mode's data path
    /// (head-relayed or mesh-direct).
    cross_shard: u64,
    /// `Deliver` frames that landed on the head's inbound FIFO.
    head_inbound: u64,
    elapsed_s: f64,
}

fn sock_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("ampnet_bench_{tag}_{}.sock", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn spawn_worker(sock: &str) -> Child {
    Command::new(env!("CARGO_BIN_EXE_ampnet"))
        .args(["worker", "--listen", sock, "--transport", "uds"])
        .env("AMP_SCALE", SCALE)
        .stdout(Stdio::null())
        .spawn()
        .expect("spawn ampnet worker")
}

fn wait_child(mut c: Child) {
    for _ in 0..100 {
        match c.try_wait().expect("try_wait") {
            Some(_) => return,
            None => std::thread::sleep(Duration::from_millis(100)),
        }
    }
    let _ = c.kill();
    let _ = c.wait();
    panic!("worker did not exit after shutdown");
}

/// One QM9 stream over a fresh 4-worker fleet; workers exit on the
/// engine's shutdown handshake, so each mode gets its own processes.
fn run(mode: &'static str, peer_links: bool) -> Result<Row> {
    let socks: Vec<String> =
        (0..WORKERS).map(|w| sock_path(&format!("{mode}_w{w}"))).collect();
    let children: Vec<Child> = socks.iter().map(|s| spawn_worker(s)).collect();
    let (model, _target) = build_model("qm9", &args_from("--seed 42"), 2 * WORKERS)?;
    let BuiltModel { graph, pumper, .. } = model;
    let spec = RemoteSpec { model: "qm9".into(), args: "--seed 42".into() };
    let mut engine = DistEngine::connect_opts(
        graph,
        TransportKind::Uds,
        &socks,
        &spec,
        &BackendSpec::native(),
        false,
        5_000,
        RecoveryOpts { peer_links, ..RecoveryOpts::disabled() },
    )?;
    let pumps: Vec<_> = (0..PUMPS).map(|i| pumper.pump(Split::Train, i)).collect();
    let t0 = Instant::now();
    engine.run_stream(StreamPlan::train(vec![pumps]), &mut FixedMak::new(MAK))?;
    let elapsed_s = t0.elapsed().as_secs_f64();
    let head_inbound = engine.relayed_delivers();
    let cross_shard = if peer_links { engine.peer_delivers() } else { head_inbound };
    drop(engine); // shutdown handshake before reaping the fleet
    for c in children {
        wait_child(c);
    }
    Ok(Row { mode, cross_shard, head_inbound, elapsed_s })
}

fn main() -> Result<()> {
    ampnet::util::logging::init();
    std::env::set_var("AMP_SCALE", SCALE);
    println!("== peer-link mesh: cross-shard Deliver path, qm9 @ {WORKERS} UDS workers ==");
    println!("   ({PUMPS} instances, mak {MAK}, native backend)");
    let rows = vec![run("relay", false)?, run("mesh", true)?];
    for r in &rows {
        println!(
            "{:<6} cross-shard {:>6} frames ({:>8.0} frames/s)  head inbound {:>6}  wall {:>6.2}s",
            r.mode,
            r.cross_shard,
            r.cross_shard as f64 / r.elapsed_s,
            r.head_inbound,
            r.elapsed_s,
        );
    }

    // The regression guards the bench exists for: the relay path funnels
    // every cross-shard frame through the head; the mesh removes them
    // from the head FIFO entirely without losing the traffic.
    let relay = &rows[0];
    let mesh = &rows[1];
    anyhow::ensure!(relay.head_inbound > 0, "relay run saw no cross-shard traffic");
    anyhow::ensure!(
        mesh.head_inbound == 0,
        "mesh regression: {} Delivers leaked onto the head FIFO",
        mesh.head_inbound
    );
    anyhow::ensure!(mesh.cross_shard > 0, "mesh run accounted for no peer Delivers");
    println!(
        "head inbound Delivers: {} (relay) -> {} (mesh)",
        relay.head_inbound, mesh.head_inbound
    );

    let out = json::obj(vec![
        ("bench", json::s("peer_mesh")),
        ("model", json::s("qm9")),
        ("workers", json::num(WORKERS as f64)),
        ("instances", json::num(PUMPS as f64)),
        ("mak", json::num(MAK as f64)),
        (
            "modes",
            json::arr(rows.iter().map(|r| {
                json::obj(vec![
                    ("mode", json::s(r.mode)),
                    ("cross_shard_frames", json::num(r.cross_shard as f64)),
                    ("cross_shard_frames_per_s", json::num(r.cross_shard as f64 / r.elapsed_s)),
                    ("head_inbound_delivers", json::num(r.head_inbound as f64)),
                    ("wall_s", json::num(r.elapsed_s)),
                ])
            })),
        ),
        (
            "head_inbound_reduction",
            json::num(relay.head_inbound.saturating_sub(mesh.head_inbound) as f64),
        ),
    ]);
    let path =
        std::env::var("AMP_BENCH_OUT").unwrap_or_else(|_| "BENCH_peer_mesh.json".to_string());
    std::fs::write(&path, out.to_string())?;
    println!("written to {path}");
    Ok(())
}
