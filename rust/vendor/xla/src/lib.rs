//! Offline stub of the `xla` (PJRT) bindings.
//!
//! Exposes exactly the API surface `ampnet::runtime::xla` consumes, but
//! every entry point that would touch PJRT fails at runtime with a clear
//! error. This keeps the crate buildable on machines without a PJRT
//! plugin; the pure-Rust native backend (`--backend native`) is fully
//! functional without it. On a machine with real XLA bindings, replace
//! this path dependency in `Cargo.toml` with the real crate — the call
//! sites compile against both.

use std::fmt;

/// Error type mirroring the real bindings' (implements `std::error::Error`
/// so `anyhow::Context` works at the call sites).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT unavailable (stub `xla` crate). Build against the real \
         xla bindings, or run with `--backend native`."
    )))
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Self {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}
