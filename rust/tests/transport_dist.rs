//! Cross-process distributed runtime, end to end (DESIGN.md §12).
//!
//! The sim-oracle equality test: a head plus two `ampnet worker`
//! processes over Unix-domain sockets must produce bit-identical losses
//! to the in-process threaded engine. At mak=1 the asynchronous stream
//! is serialized — one instance in flight, deterministic admission and
//! gradient-arrival order — so any divergence is a transport bug
//! (serialization loss, reordering, a worker rebuilding a different
//! model), not nondeterminism.
//!
//! Also covered: the inproc carrier (same protocol, no sockets),
//! heartbeat-timeout liveness (a killed worker surfaces
//! `TransportError::PeerLost` instead of hanging the stream), the
//! ISSUE 7 fault-tolerance pair — a scripted mid-epoch worker kill that
//! recovers and converges within 5% of the unfaulted run, and the same
//! kill with recovery disabled still surfacing the typed `PeerLost` —
//! and the peer-link mesh (DESIGN.md §16): `--peer-links on` must stay
//! bit-equal to the head-relay oracle at mak=1, keep the head out of
//! the `Deliver` path entirely, and recover from a scripted
//! `kill:link=A-B` with exact instance accounting.

use std::process::{Child, Command, Stdio};
use std::time::Duration;

use ampnet::data::Split;
use ampnet::launcher::{args_from, build_model};
use ampnet::models::BuiltModel;
use ampnet::runtime::BackendSpec;
use ampnet::scheduler::{Engine, EngineKind, FixedMak, StreamPlan};
use ampnet::train::{AmpTrainer, RunReport, TrainCfg};
use ampnet::transport::{DistEngine, RecoveryOpts, RemoteSpec, TransportError, TransportKind};

/// One value for the whole test binary: parallel test threads share the
/// process environment, so every test must agree on the dataset scale.
const SCALE: &str = "0.002";

fn sock_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("ampnet_{tag}_{}.sock", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn spawn_worker(sock: &str) -> Child {
    Command::new(env!("CARGO_BIN_EXE_ampnet"))
        .args(["worker", "--listen", sock, "--transport", "uds"])
        .env("AMP_SCALE", SCALE)
        .stdout(Stdio::null())
        .spawn()
        .expect("spawn ampnet worker")
}

/// Wait for an orderly exit after the engine's shutdown handshake.
fn wait_child(mut c: Child) {
    for _ in 0..100 {
        match c.try_wait().expect("try_wait") {
            Some(_) => return,
            None => std::thread::sleep(Duration::from_millis(100)),
        }
    }
    let _ = c.kill();
    let _ = c.wait();
    panic!("worker did not exit after shutdown");
}

/// Train the quickstart MLP for two epochs at mak=1 and return the
/// report. `transport: None` is the in-process threaded oracle; `tweak`
/// adjusts the shared config (fault plans, recovery switches).
fn run_report_cfg(
    transport: Option<TransportKind>,
    workers_remote: Vec<String>,
    tweak: impl FnOnce(&mut TrainCfg),
) -> anyhow::Result<RunReport> {
    std::env::set_var("AMP_SCALE", SCALE);
    let (model, target) = build_model("mlp", &args_from("--seed 42"), 8).unwrap();
    let mut cfg = TrainCfg::new(BackendSpec::native(), 1, 2, target);
    cfg.engine = EngineKind::Threaded;
    cfg.early_stop = false;
    cfg.max_train_instances = Some(40);
    cfg.max_valid_instances = Some(50);
    cfg.transport = transport;
    cfg.workers_remote = workers_remote;
    cfg.remote = Some(RemoteSpec { model: "mlp".into(), args: "--seed 42".into() });
    tweak(&mut cfg);
    let (report, engine) = AmpTrainer::run(model, &cfg)?;
    drop(engine); // Shutdown + close before the caller waits on children
    Ok(report)
}

fn run_report(transport: Option<TransportKind>, workers_remote: Vec<String>) -> RunReport {
    run_report_cfg(transport, workers_remote, |_| {}).unwrap()
}

/// Loss curves must match to the bit; wall-clock-derived fields
/// (throughput, busy seconds) legitimately differ across processes.
fn assert_bit_equal(oracle: &RunReport, dist: &RunReport) {
    assert_eq!(oracle.epochs.len(), dist.epochs.len());
    for (a, b) in oracle.epochs.iter().zip(&dist.epochs) {
        let e = a.epoch;
        assert_eq!(a.train.instances, b.train.instances, "epoch {e}: train instances");
        assert_eq!(a.train.loss_events, b.train.loss_events, "epoch {e}: loss events");
        assert_eq!(
            a.train.loss_sum.to_bits(),
            b.train.loss_sum.to_bits(),
            "epoch {e}: train loss diverged ({} vs {})",
            a.train.loss_sum,
            b.train.loss_sum
        );
        assert_eq!(a.train.updates, b.train.updates, "epoch {e}: update count");
        assert_eq!((a.train.correct, a.train.count), (b.train.correct, b.train.count));
        assert_eq!(a.valid.instances, b.valid.instances, "epoch {e}: valid instances");
        assert_eq!(
            a.valid.loss_sum.to_bits(),
            b.valid.loss_sum.to_bits(),
            "epoch {e}: valid loss diverged ({} vs {})",
            a.valid.loss_sum,
            b.valid.loss_sum
        );
        assert_eq!(
            a.valid_accuracy.to_bits(),
            b.valid_accuracy.to_bits(),
            "epoch {e}: valid accuracy diverged"
        );
    }
}

#[test]
fn uds_head_and_two_workers_match_threaded_engine_bit_exactly() {
    let s0 = sock_path("uds_w0");
    let s1 = sock_path("uds_w1");
    let w0 = spawn_worker(&s0);
    let w1 = spawn_worker(&s1);
    let oracle = run_report(None, vec![]);
    let dist = run_report(Some(TransportKind::Uds), vec![s0, s1]);
    assert_bit_equal(&oracle, &dist);
    wait_child(w0);
    wait_child(w1);
}

#[test]
fn inproc_transport_matches_threaded_engine_bit_exactly() {
    let oracle = run_report(None, vec![]);
    let dist = run_report(Some(TransportKind::InProc), vec![]);
    assert_bit_equal(&oracle, &dist);
}

/// ISSUE 7 acceptance: a deterministic mid-epoch worker kill over UDS
/// recovers — the lost shard's in-flight instances are cancelled and
/// re-admitted, the fleet warm-restarts from the last snapshot, and the
/// final train loss lands within 5% relative of the unfaulted run.
#[test]
fn scripted_kill_recovers_and_converges() {
    let s0 = sock_path("rec_w0");
    let s1 = sock_path("rec_w1");
    let w0 = spawn_worker(&s0);
    let w1 = spawn_worker(&s1);
    let clean =
        run_report_cfg(Some(TransportKind::Uds), vec![s0.clone(), s1.clone()], |_| {}).unwrap();
    wait_child(w0);
    wait_child(w1);
    // Fresh worker pair: the clean run's shutdown handshake ended the
    // first one. The faulted run's kill only drops the connection — the
    // worker process re-listens and is re-adopted by recovery.
    let w0 = spawn_worker(&s0);
    let w1 = spawn_worker(&s1);
    let faulted = run_report_cfg(Some(TransportKind::Uds), vec![s0, s1], |cfg| {
        cfg.fault_plan = Some("kill:worker=1@step=3".parse().unwrap());
        cfg.liveness_ms = 2_000;
    })
    .expect("faulted run recovers instead of aborting");
    let d = faulted.degraded.as_ref().expect("faulted run reports a Degraded section");
    assert_eq!(d.lost_workers, vec![1], "exactly one incident, shard 1: {d:?}");
    assert!(d.readmitted_instances >= 1, "in-flight instances re-admitted: {d:?}");
    assert!(d.reconnects >= 2, "recovery re-attaches the whole fleet: {d:?}");
    assert!(d.recovery_seconds > 0.0, "recovery wall-time recorded: {d:?}");
    let clean_last = clean.epochs.last().unwrap();
    let fault_last = faulted.epochs.last().unwrap();
    // At-least-once re-admission replays work, but instance accounting
    // stays exact: the cancelled retire is ignored, the re-run's counts.
    assert_eq!(fault_last.train.instances, clean_last.train.instances);
    let clean_loss = clean_last.train.mean_loss();
    let fault_loss = fault_last.train.mean_loss();
    let rel = (fault_loss - clean_loss).abs() / clean_loss.abs().max(1e-9);
    assert!(
        rel <= 0.05,
        "final train loss diverged {rel:.4} rel (clean {clean_loss}, faulted {fault_loss})"
    );
    wait_child(w0);
    wait_child(w1);
}

/// The worker→head direction (`dir=in`): the connection dies while the
/// head is *reading* shard 1's results — mid-reply rather than mid-send,
/// so the failure surfaces through the pump thread instead of a failed
/// send. Recovery must engage identically: cancel + re-admit, redial,
/// warm-restart, exact instance accounting.
#[test]
fn scripted_inbound_kill_recovers() {
    let s0 = sock_path("recin_w0");
    let s1 = sock_path("recin_w1");
    let w0 = spawn_worker(&s0);
    let w1 = spawn_worker(&s1);
    let faulted = run_report_cfg(Some(TransportKind::Uds), vec![s0, s1], |cfg| {
        cfg.fault_plan = Some("kill:worker=1@step=3,dir=in".parse().unwrap());
        cfg.liveness_ms = 2_000;
    })
    .expect("inbound-faulted run recovers instead of aborting");
    let d = faulted.degraded.as_ref().expect("faulted run reports a Degraded section");
    assert_eq!(d.lost_workers, vec![1], "exactly one incident, shard 1: {d:?}");
    assert!(d.reconnects >= 2, "recovery re-attaches the whole fleet: {d:?}");
    assert!(d.recovery_seconds > 0.0, "recovery wall-time recorded: {d:?}");
    let last = faulted.epochs.last().unwrap();
    assert_eq!(last.train.instances, 40, "instance accounting stays exact after replay");
    wait_child(w0);
    wait_child(w1);
}

/// The same scripted kill with recovery disabled must surface the typed
/// `PeerLost` — fault injection applies regardless of `recover`.
#[test]
fn scripted_kill_without_recovery_surfaces_peer_lost() {
    let s0 = sock_path("norec_w0");
    let s1 = sock_path("norec_w1");
    let w0 = spawn_worker(&s0);
    let mut w1 = spawn_worker(&s1);
    let err = run_report_cfg(Some(TransportKind::Uds), vec![s0, s1], |cfg| {
        cfg.recover = false;
        cfg.fault_plan = Some("kill:worker=1@step=3".parse().unwrap());
        cfg.liveness_ms = 1_500;
    })
    .expect_err("faulted run without recovery must abort");
    assert!(
        matches!(
            err.downcast_ref::<TransportError>(),
            Some(TransportError::PeerLost { worker: 1 })
        ),
        "expected PeerLost for worker 1, got: {err:#}"
    );
    wait_child(w0);
    // Worker 1 only lost its connection, so it is re-listening — there
    // is no head left to shut it down.
    let _ = w1.kill();
    let _ = w1.wait();
}

/// ISSUE 10 acceptance: with the peer mesh on, cross-shard `Deliver`s
/// flow worker→worker — a different wire topology — yet at mak=1 the
/// stream is serialized and the per-link FIFO plus the head's
/// `PeerDrain` barriers must reproduce the head-relay schedule exactly.
/// Any divergence is a mesh bug (reordering, a leaked in-flight frame
/// across a watermark), not nondeterminism.
#[test]
fn uds_mesh_matches_head_relay_oracle_bit_exactly() {
    let s0 = sock_path("mesh_w0");
    let s1 = sock_path("mesh_w1");
    let w0 = spawn_worker(&s0);
    let w1 = spawn_worker(&s1);
    let relay =
        run_report_cfg(Some(TransportKind::Uds), vec![s0.clone(), s1.clone()], |_| {}).unwrap();
    wait_child(w0);
    wait_child(w1);
    // Fresh worker pair: the relay run's shutdown handshake ended the
    // first one.
    let w0 = spawn_worker(&s0);
    let w1 = spawn_worker(&s1);
    let mesh = run_report_cfg(Some(TransportKind::Uds), vec![s0, s1], |cfg| {
        cfg.peer_links = true;
    })
    .unwrap();
    assert_bit_equal(&relay, &mesh);
    wait_child(w0);
    wait_child(w1);
}

/// ISSUE 10 acceptance: with `--peer-links on` the head receives zero
/// inbound `Deliver` frames — every cross-shard hop rides the mesh —
/// while the `PeerDrain` barrier proves a non-zero number of mesh
/// `Deliver`s actually landed (the traffic moved, it didn't vanish).
#[test]
fn mesh_keeps_head_out_of_the_deliver_path() {
    std::env::set_var("AMP_SCALE", SCALE);
    let s0 = sock_path("meshd_w0");
    let s1 = sock_path("meshd_w1");
    let w0 = spawn_worker(&s0);
    let w1 = spawn_worker(&s1);
    let (model, _target) = build_model("mlp", &args_from("--seed 42"), 8).unwrap();
    let BuiltModel { graph, pumper, .. } = model;
    let spec = RemoteSpec { model: "mlp".into(), args: "--seed 42".into() };
    let mut engine = DistEngine::connect_opts(
        graph,
        TransportKind::Uds,
        &[s0, s1],
        &spec,
        &BackendSpec::native(),
        false,
        2_000,
        RecoveryOpts { peer_links: true, ..RecoveryOpts::disabled() },
    )
    .expect("handshake with both shards, mesh on");
    let pumps: Vec<_> = (0..10).map(|i| pumper.pump(Split::Train, i)).collect();
    engine
        .run_stream(StreamPlan::train(vec![pumps]), &mut FixedMak::new(1))
        .expect("mesh stream completes");
    assert_eq!(
        engine.relayed_delivers(),
        0,
        "head must relay no Delivers while the mesh is on"
    );
    assert!(
        engine.peer_delivers() > 0,
        "drain barrier must account for the mesh traffic that replaced the relay"
    );
    drop(engine);
    wait_child(w0);
    wait_child(w1);
}

/// A scripted peer-link kill (`kill:link=0-1@step=1`): worker 0's first
/// cross-shard `Deliver` to worker 1 dies on the dialed link, the
/// worker surfaces it as a typed `Abort` (never a silent drop), and §13
/// recovery treats it as losing shard 0 — cancel + re-admit, redial the
/// fleet *and* its mesh, warm-restart — with exact instance accounting.
#[test]
fn scripted_link_kill_recovers_with_exact_instances() {
    let s0 = sock_path("meshk_w0");
    let s1 = sock_path("meshk_w1");
    let w0 = spawn_worker(&s0);
    let w1 = spawn_worker(&s1);
    let faulted = run_report_cfg(Some(TransportKind::Uds), vec![s0, s1], |cfg| {
        cfg.peer_links = true;
        cfg.fault_plan = Some("kill:link=0-1@step=1".parse().unwrap());
        cfg.liveness_ms = 2_000;
    })
    .expect("link-faulted run recovers instead of aborting");
    let d = faulted.degraded.as_ref().expect("faulted run reports a Degraded section");
    assert_eq!(d.lost_workers, vec![0], "the dialing side of the dead link is lost: {d:?}");
    assert!(d.reconnects >= 2, "recovery re-attaches the whole fleet: {d:?}");
    let last = faulted.epochs.last().unwrap();
    assert_eq!(last.train.instances, 40, "instance accounting stays exact after replay");
    wait_child(w0);
    wait_child(w1);
}

#[test]
fn killed_worker_surfaces_peer_lost() {
    std::env::set_var("AMP_SCALE", SCALE);
    let s0 = sock_path("live_w0");
    let s1 = sock_path("live_w1");
    let w0 = spawn_worker(&s0);
    let mut w1 = spawn_worker(&s1);
    let (model, _target) = build_model("mlp", &args_from("--seed 42"), 8).unwrap();
    let BuiltModel { graph, pumper, .. } = model;
    let spec = RemoteSpec { model: "mlp".into(), args: "--seed 42".into() };
    let mut engine = DistEngine::connect(
        graph,
        TransportKind::Uds,
        &[s0, s1],
        &spec,
        &BackendSpec::native(),
        false,
        1500,
    )
    .expect("handshake with both shards");
    // Kill shard 1 after the handshake: the stream must abort with a
    // typed PeerLost naming the dead shard, not hang on lost messages.
    w1.kill().expect("kill worker 1");
    w1.wait().expect("reap worker 1");
    let pumps: Vec<_> = (0..10).map(|i| pumper.pump(Split::Train, i)).collect();
    let err = engine
        .run_stream(StreamPlan::train(vec![pumps]), &mut FixedMak::new(1))
        .expect_err("stream over a dead shard must abort");
    assert!(
        matches!(
            err.downcast_ref::<TransportError>(),
            Some(TransportError::PeerLost { worker: 1 })
        ),
        "expected PeerLost for worker 1, got: {err:#}"
    );
    assert!(err.to_string().contains("peer lost"), "{err}");
    drop(engine);
    wait_child(w0);
}
