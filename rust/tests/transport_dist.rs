//! Cross-process distributed runtime, end to end (DESIGN.md §12).
//!
//! The sim-oracle equality test: a head plus two `ampnet worker`
//! processes over Unix-domain sockets must produce bit-identical losses
//! to the in-process threaded engine. At mak=1 the asynchronous stream
//! is serialized — one instance in flight, deterministic admission and
//! gradient-arrival order — so any divergence is a transport bug
//! (serialization loss, reordering, a worker rebuilding a different
//! model), not nondeterminism.
//!
//! Also covered: the inproc carrier (same protocol, no sockets) and
//! heartbeat-timeout liveness (a killed worker surfaces
//! `TransportError::PeerLost` instead of hanging the stream).

use std::process::{Child, Command, Stdio};
use std::time::Duration;

use ampnet::data::Split;
use ampnet::launcher::{args_from, build_model};
use ampnet::models::BuiltModel;
use ampnet::runtime::BackendSpec;
use ampnet::scheduler::{Engine, EngineKind, FixedMak, StreamPlan};
use ampnet::train::{AmpTrainer, RunReport, TrainCfg};
use ampnet::transport::{DistEngine, RemoteSpec, TransportError, TransportKind};

/// One value for the whole test binary: parallel test threads share the
/// process environment, so every test must agree on the dataset scale.
const SCALE: &str = "0.002";

fn sock_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("ampnet_{tag}_{}.sock", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn spawn_worker(sock: &str) -> Child {
    Command::new(env!("CARGO_BIN_EXE_ampnet"))
        .args(["worker", "--listen", sock, "--transport", "uds"])
        .env("AMP_SCALE", SCALE)
        .stdout(Stdio::null())
        .spawn()
        .expect("spawn ampnet worker")
}

/// Wait for an orderly exit after the engine's shutdown handshake.
fn wait_child(mut c: Child) {
    for _ in 0..100 {
        match c.try_wait().expect("try_wait") {
            Some(_) => return,
            None => std::thread::sleep(Duration::from_millis(100)),
        }
    }
    let _ = c.kill();
    let _ = c.wait();
    panic!("worker did not exit after shutdown");
}

/// Train the quickstart MLP for two epochs at mak=1 and return the
/// report. `transport: None` is the in-process threaded oracle.
fn run_report(transport: Option<TransportKind>, workers_remote: Vec<String>) -> RunReport {
    std::env::set_var("AMP_SCALE", SCALE);
    let (model, target) = build_model("mlp", &args_from("--seed 42"), 8).unwrap();
    let mut cfg = TrainCfg::new(BackendSpec::native(), 1, 2, target);
    cfg.engine = EngineKind::Threaded;
    cfg.early_stop = false;
    cfg.max_train_instances = Some(40);
    cfg.max_valid_instances = Some(50);
    cfg.transport = transport;
    cfg.workers_remote = workers_remote;
    cfg.remote = Some(RemoteSpec { model: "mlp".into(), args: "--seed 42".into() });
    let (report, engine) = AmpTrainer::run(model, &cfg).unwrap();
    drop(engine); // Shutdown + close before the caller waits on children
    report
}

/// Loss curves must match to the bit; wall-clock-derived fields
/// (throughput, busy seconds) legitimately differ across processes.
fn assert_bit_equal(oracle: &RunReport, dist: &RunReport) {
    assert_eq!(oracle.epochs.len(), dist.epochs.len());
    for (a, b) in oracle.epochs.iter().zip(&dist.epochs) {
        let e = a.epoch;
        assert_eq!(a.train.instances, b.train.instances, "epoch {e}: train instances");
        assert_eq!(a.train.loss_events, b.train.loss_events, "epoch {e}: loss events");
        assert_eq!(
            a.train.loss_sum.to_bits(),
            b.train.loss_sum.to_bits(),
            "epoch {e}: train loss diverged ({} vs {})",
            a.train.loss_sum,
            b.train.loss_sum
        );
        assert_eq!(a.train.updates, b.train.updates, "epoch {e}: update count");
        assert_eq!((a.train.correct, a.train.count), (b.train.correct, b.train.count));
        assert_eq!(a.valid.instances, b.valid.instances, "epoch {e}: valid instances");
        assert_eq!(
            a.valid.loss_sum.to_bits(),
            b.valid.loss_sum.to_bits(),
            "epoch {e}: valid loss diverged ({} vs {})",
            a.valid.loss_sum,
            b.valid.loss_sum
        );
        assert_eq!(
            a.valid_accuracy.to_bits(),
            b.valid_accuracy.to_bits(),
            "epoch {e}: valid accuracy diverged"
        );
    }
}

#[test]
fn uds_head_and_two_workers_match_threaded_engine_bit_exactly() {
    let s0 = sock_path("uds_w0");
    let s1 = sock_path("uds_w1");
    let w0 = spawn_worker(&s0);
    let w1 = spawn_worker(&s1);
    let oracle = run_report(None, vec![]);
    let dist = run_report(Some(TransportKind::Uds), vec![s0, s1]);
    assert_bit_equal(&oracle, &dist);
    wait_child(w0);
    wait_child(w1);
}

#[test]
fn inproc_transport_matches_threaded_engine_bit_exactly() {
    let oracle = run_report(None, vec![]);
    let dist = run_report(Some(TransportKind::InProc), vec![]);
    assert_bit_equal(&oracle, &dist);
}

#[test]
fn killed_worker_surfaces_peer_lost() {
    std::env::set_var("AMP_SCALE", SCALE);
    let s0 = sock_path("live_w0");
    let s1 = sock_path("live_w1");
    let w0 = spawn_worker(&s0);
    let mut w1 = spawn_worker(&s1);
    let (model, _target) = build_model("mlp", &args_from("--seed 42"), 8).unwrap();
    let BuiltModel { graph, pumper, .. } = model;
    let spec = RemoteSpec { model: "mlp".into(), args: "--seed 42".into() };
    let mut engine = DistEngine::connect(
        graph,
        TransportKind::Uds,
        &[s0, s1],
        &spec,
        &BackendSpec::native(),
        false,
        1500,
    )
    .expect("handshake with both shards");
    // Kill shard 1 after the handshake: the stream must abort with a
    // typed PeerLost naming the dead shard, not hang on lost messages.
    w1.kill().expect("kill worker 1");
    w1.wait().expect("reap worker 1");
    let pumps: Vec<_> = (0..10).map(|i| pumper.pump(Split::Train, i)).collect();
    let err = engine
        .run_stream(StreamPlan::train(vec![pumps]), &mut FixedMak::new(1))
        .expect_err("stream over a dead shard must abort");
    assert!(
        matches!(
            err.downcast_ref::<TransportError>(),
            Some(TransportError::PeerLost { worker: 1 })
        ),
        "expected PeerLost for worker 1, got: {err:#}"
    );
    assert!(err.to_string().contains("peer lost"), "{err}");
    drop(engine);
    wait_child(w0);
}
