//! Engine-level invariants, checked on both the simulator and the
//! threaded runtime with the native backend (no artifacts needed):
//!
//! * every pumped message retires (fwd/bwd state invariant, §4);
//! * no cached keys survive an epoch (leak freedom);
//! * max_active_keys truly bounds in-flight instances;
//! * with one flush-time update, both engines and any mak produce
//!   *identical* parameters (gradient accumulation is order-independent);
//! * lane invariants (DESIGN.md §11): interleaved eval losses exactly
//!   match the drained-eval baseline in the deterministic sim engine,
//!   the eval lane never mutates parameters or optimizer state, per-lane
//!   watermarks separate under duplicate ids, and hop/backlog telemetry
//!   reaches the admission policy;
//! * randomized pipeline property: arbitrary interleavings retire.

use ampnet::data::{MnistLike, Split};
use ampnet::ir::PumpSet;
use ampnet::models::{mlp, rnn, ModelCfg};
use ampnet::optim::OptState;
use ampnet::runtime::BackendSpec;
use ampnet::scheduler::{
    build_engine, AdmissionKind, AdmissionPolicy, ControlObs, Engine, EngineKind, EpochKind,
    EpochStats, FixedMak, Lane, StalenessKind, StreamPlan,
};
use ampnet::tensor::ops::rel_diff;

fn mlp_model(muf: usize) -> ampnet::models::BuiltModel {
    let mut cfg = ModelCfg::default();
    cfg.muf = muf;
    mlp::build(&cfg, MnistLike::new(0, 600, 200, 100), 4).unwrap()
}

fn pumps_for(pumper: &dyn ampnet::models::Pumper, n: usize) -> Vec<PumpSet> {
    (0..n).map(|i| pumper.pump(Split::Train, i)).collect()
}

#[test]
fn both_engines_retire_and_do_not_leak() {
    for engine_kind in [EngineKind::Sim, EngineKind::Threaded] {
        let model = mlp_model(100);
        let mut eng =
            build_engine(engine_kind, model.graph, BackendSpec::native(), false).unwrap();
        let stats = eng
            .run_epoch(pumps_for(model.pumper.as_ref(), 6), 3, EpochKind::Train)
            .unwrap_or_else(|e| panic!("{engine_kind}: {e:#}"));
        assert_eq!(stats.instances, 6, "{engine_kind}");
        assert_eq!(stats.loss_events, 6, "{engine_kind}");
        assert!(stats.updates > 0, "{engine_kind}");
        assert_eq!(eng.cached_keys().unwrap(), 0, "{engine_kind} leaked");
    }
}

#[test]
fn engines_agree_bitwise_when_updates_are_deferred() {
    // One update at flush time => gradient sum is message-order-invariant
    // => sim and threaded (any mak) give identical parameters.
    let collect = |engine_kind: EngineKind, mak: usize| -> Vec<ampnet::tensor::Tensor> {
        let model = mlp_model(1_000_000_000);
        let n_nodes = model.graph.nodes.len();
        let mut eng =
            build_engine(engine_kind, model.graph, BackendSpec::native(), false).unwrap();
        eng.run_epoch(pumps_for(model.pumper.as_ref(), 4), mak, EpochKind::Train).unwrap();
        let mut out = Vec::new();
        for node in 0..n_nodes {
            out.extend(eng.params_of(node).unwrap());
        }
        out
    };
    let a = collect(EngineKind::Sim, 1);
    let b = collect(EngineKind::Sim, 4);
    let c = collect(EngineKind::Threaded, 4);
    assert_eq!(a.len(), b.len());
    for ((x, y), z) in a.iter().zip(&b).zip(&c) {
        assert!(rel_diff(x, y) < 1e-6, "sim mak1 vs mak4");
        assert!(rel_diff(x, z) < 1e-6, "sim vs threaded");
    }
}

#[test]
fn mak_bounds_inflight_instances() {
    // Indirect check through the controller: a mak=1 run must show
    // strictly serialized losses == instances, and staleness 0 for MLP.
    let model = mlp_model(100);
    let mut eng = build_engine(EngineKind::Sim, model.graph, BackendSpec::native(), false).unwrap();
    let stats = eng.run_epoch(pumps_for(model.pumper.as_ref(), 5), 1, EpochKind::Train).unwrap();
    assert_eq!(stats.instances, 5);
    assert_eq!(
        stats.mean_staleness(),
        0.0,
        "synchronous MLP cannot see stale gradients"
    );
}

#[test]
fn async_runs_exhibit_staleness_on_deep_pipelines() {
    // With many instances in flight and muf=1 updates, some backward
    // passes must observe parameter updates that happened since their
    // forward pass.
    let model = mlp_model(1);
    let mut eng = build_engine(EngineKind::Sim, model.graph, BackendSpec::native(), false).unwrap();
    let stats = eng.run_epoch(pumps_for(model.pumper.as_ref(), 6), 6, EpochKind::Train).unwrap();
    assert!(
        stats.staleness_sum > 0,
        "expected nonzero staleness with mak=6, muf=1"
    );
}

#[test]
fn batched_inbox_preserves_backward_priority() {
    // The threaded engine's inbox drains in batches: one lock swap can
    // deliver forward and backward messages mixed together. Backward
    // priority must survive that. With every node on ONE worker the
    // trace is a serial schedule, and strict backward-first processing
    // implies each backward chain, once initiated by the loss, runs to
    // completion before any queued forward resumes: every maximal run of
    // backward entries must be exactly 3 long (the MLP's three linear
    // layers), one run per instance.
    let mut cfg = ModelCfg::default();
    cfg.muf = 100;
    let model = mlp::build(&cfg, MnistLike::new(0, 600, 200, 100), 1).unwrap();
    let n = 6;
    let mut eng =
        build_engine(EngineKind::Threaded, model.graph, BackendSpec::native(), true).unwrap();
    let stats = eng
        .run_epoch(pumps_for(model.pumper.as_ref(), n), n, EpochKind::Train)
        .unwrap();
    assert_eq!(stats.instances, n);
    assert!(!stats.trace.is_empty(), "tracing was enabled");
    assert!(
        !stats.node_labels.is_empty(),
        "labels are resolved once at flush time"
    );
    assert!(
        stats.trace.iter().all(|e| e.worker == 0),
        "single-worker schedule expected"
    );
    let mut runs: Vec<usize> = Vec::new();
    let mut cur = 0usize;
    for e in &stats.trace {
        if e.backward {
            cur += 1;
        } else if cur > 0 {
            runs.push(cur);
            cur = 0;
        }
    }
    if cur > 0 {
        runs.push(cur);
    }
    assert_eq!(runs.len(), n, "one backward chain per instance: {runs:?}");
    assert!(
        runs.iter().all(|&r| r == 3),
        "a forward ran while backward messages were queued: {runs:?}"
    );
    assert_eq!(eng.cached_keys().unwrap(), 0);
}

#[test]
fn rnn_loop_retires_in_threaded_engine() {
    let data = ampnet::data::ListRedGen::new(0, 300, 100, 100);
    let model = rnn::build(&ModelCfg::default(), data, 8, 2).unwrap();
    let mut eng =
        build_engine(EngineKind::Threaded, model.graph, BackendSpec::native(), false).unwrap();
    let pumps: Vec<PumpSet> =
        (0..3).map(|i| model.pumper.pump(Split::Train, i)).collect();
    let stats = eng.run_epoch(pumps, 4, EpochKind::Train).unwrap();
    assert_eq!(stats.instances, 3);
    assert_eq!(eng.cached_keys().unwrap(), 0);
    // params can be fetched and written back across threads
    ampnet::scheduler::sync_replicas(eng.as_mut(), &model.replica_groups).unwrap();
}

#[test]
fn streaming_admission_retires_every_instance_exactly_once_per_epoch() {
    // Three epochs pipelined through one run_stream call: instances of
    // epoch e+1 are admitted while epoch e's tail retires, yet each
    // epoch's watermark accounting must see exactly its own population.
    let n = 6;
    for engine_kind in [EngineKind::Sim, EngineKind::Threaded] {
        let model = mlp_model(100);
        let mut eng =
            build_engine(engine_kind, model.graph, BackendSpec::native(), false).unwrap();
        let epochs: Vec<Vec<PumpSet>> =
            (0..3).map(|_| pumps_for(model.pumper.as_ref(), n)).collect();
        let mut admission = AdmissionKind::Fixed.policy(4);
        let stats = eng
            .run_stream(StreamPlan::train(epochs), admission.as_mut())
            .unwrap_or_else(|e| panic!("{engine_kind}: {e:#}"));
        assert_eq!(stats.len(), 3, "{engine_kind}: one stats entry per epoch");
        for (e, s) in stats.iter().enumerate() {
            assert_eq!(s.instances, n, "{engine_kind}: epoch {e} retire count");
            assert_eq!(s.loss_events, n, "{engine_kind}: epoch {e} loss events");
        }
        assert_eq!(eng.cached_keys().unwrap(), 0, "{engine_kind} leaked");
    }
}

#[test]
fn aimd_never_exceeds_its_ceiling() {
    // Generous staleness bound => pure additive increase; the in-flight
    // population must still never cross the configured ceiling.
    let ceiling = 3;
    let model = mlp_model(1);
    let mut eng = build_engine(EngineKind::Sim, model.graph, BackendSpec::native(), false).unwrap();
    let epochs: Vec<Vec<PumpSet>> =
        (0..4).map(|_| pumps_for(model.pumper.as_ref(), 6)).collect();
    let mut admission = AdmissionKind::Aimd { staleness_bound: 1e9 }.policy(ceiling);
    let stats = eng.run_stream(StreamPlan::train(epochs), admission.as_mut()).unwrap();
    let total: usize = stats.iter().map(|s| s.instances).sum();
    assert_eq!(total, 24);
    for (e, s) in stats.iter().enumerate() {
        assert!(
            s.max_active <= ceiling,
            "epoch {e}: {} instances in flight above ceiling {ceiling}",
            s.max_active
        );
    }
    assert!(
        stats.iter().any(|s| s.max_active == ceiling),
        "additive increase should reach the ceiling"
    );
    assert_eq!(eng.cached_keys().unwrap(), 0);
}

#[test]
fn clip_policy_bounds_applied_staleness_under_batched_drains() {
    // Threaded engine: BatchQueue delivers mixed fwd/bwd batches and
    // muf=1 updates fire on every backward, so staleness is rampant.
    // With `clip:1` the *applied* staleness must stay within the bound
    // and over-stale contributions must be counted as dropped.
    let mut cfg = ModelCfg::default();
    cfg.muf = 1;
    cfg.staleness = StalenessKind::Clip { max_staleness: 1 };
    let model = mlp::build(&cfg, MnistLike::new(0, 800, 200, 100), 4).unwrap();
    let mut eng =
        build_engine(EngineKind::Threaded, model.graph, BackendSpec::native(), false).unwrap();
    let epochs: Vec<Vec<PumpSet>> =
        (0..3).map(|_| pumps_for(model.pumper.as_ref(), 8)).collect();
    let mut admission = AdmissionKind::Fixed.policy(8);
    let stats = eng.run_stream(StreamPlan::train(epochs), admission.as_mut()).unwrap();
    let smax = stats.iter().map(|s| s.staleness_max).max().unwrap();
    assert!(smax <= 1, "applied staleness {smax} exceeds the clip bound");
    let total: usize = stats.iter().map(|s| s.instances).sum();
    assert_eq!(total, 24, "dropping gradients must not affect retirement");
    assert_eq!(eng.cached_keys().unwrap(), 0);
}

#[test]
fn aimd_streaming_sustains_higher_occupancy_than_fixed_mak_drains() {
    // The acceptance experiment: at an equal MAK ceiling, AdaptiveAimd +
    // LrDiscount driving a cross-epoch stream must sustain higher mean
    // occupancy than the classic FixedMak cycle that drains the pipeline
    // to zero at every epoch boundary — while the staleness the AIMD
    // controller admits stays within its configured bound.
    let ceiling = 4;
    let n = 10;
    let n_epochs = 8;
    let bound = 6.0;
    let agg = |stats: &[EpochStats]| -> (f64, f64) {
        let m = EpochStats::merged(stats);
        (m.mean_occupancy(), m.mean_staleness())
    };

    // Path A: today's semantics — FixedMak, drain-to-zero per epoch.
    let fixed_stats: Vec<EpochStats> = {
        let model = mlp_model(1);
        let mut eng =
            build_engine(EngineKind::Sim, model.graph, BackendSpec::native(), false).unwrap();
        (0..n_epochs)
            .map(|_| {
                eng.run_epoch(pumps_for(model.pumper.as_ref(), n), ceiling, EpochKind::Train)
                    .unwrap()
            })
            .collect()
    };
    // Path B: the new control plane — AIMD admission over one stream,
    // LrDiscount staleness policy in every ParamSet.
    let aimd_stats: Vec<EpochStats> = {
        let mut cfg = ModelCfg::default();
        cfg.muf = 1;
        cfg.staleness = StalenessKind::LrDiscount { alpha: 0.5 };
        let model = mlp::build(&cfg, MnistLike::new(0, 600, 200, 100), 4).unwrap();
        let mut eng =
            build_engine(EngineKind::Sim, model.graph, BackendSpec::native(), false).unwrap();
        let epochs: Vec<Vec<PumpSet>> =
            (0..n_epochs).map(|_| pumps_for(model.pumper.as_ref(), n)).collect();
        let mut admission = AdmissionKind::Aimd { staleness_bound: bound }.policy(ceiling);
        eng.run_stream(StreamPlan::train(epochs), admission.as_mut()).unwrap()
    };
    let (fixed_occ, _) = agg(&fixed_stats);
    let (aimd_occ, aimd_stale) = agg(&aimd_stats);
    assert!(
        aimd_occ > fixed_occ,
        "streaming AIMD occupancy {aimd_occ:.3} should beat drain-per-epoch FixedMak {fixed_occ:.3} \
         at equal ceiling {ceiling}"
    );
    assert!(
        aimd_stale <= bound,
        "mean applied staleness {aimd_stale:.3} exceeds the configured bound {bound}"
    );
    let total: usize = aimd_stats.iter().map(|s| s.instances).sum();
    assert_eq!(total, n_epochs * n);
}

#[test]
fn streaming_attributes_busy_seconds_to_each_epoch() {
    // Satellite of ISSUE 4: worker busy counters are snapshotted at
    // watermark closes, so per-epoch utilization no longer collapses
    // onto the stream's last epoch.
    let n = 6;
    for engine_kind in [EngineKind::Sim, EngineKind::Threaded] {
        let model = mlp_model(100);
        let mut eng =
            build_engine(engine_kind, model.graph, BackendSpec::native(), false).unwrap();
        let epochs: Vec<Vec<PumpSet>> =
            (0..3).map(|_| pumps_for(model.pumper.as_ref(), n)).collect();
        let mut admission = AdmissionKind::Fixed.policy(2);
        let stats = eng.run_stream(StreamPlan::train(epochs), admission.as_mut()).unwrap();
        for (e, s) in stats.iter().enumerate() {
            let busy: f64 = s.worker_busy.iter().sum();
            assert!(
                busy > 0.0,
                "{engine_kind}: epoch {e} attributed no busy time (worker_busy {:?})",
                s.worker_busy
            );
        }
        // totals must be conserved: per-epoch shares sum to the run total
        let per_epoch: f64 =
            stats.iter().map(|s| s.worker_busy.iter().sum::<f64>()).sum();
        assert!(per_epoch > 0.0);
        // each epoch processed work, so messages attribute per epoch too
        for (e, s) in stats.iter().enumerate() {
            assert!(s.messages > 0, "{engine_kind}: epoch {e} shows zero messages");
        }
    }
}

#[test]
fn per_edge_staleness_histograms_reach_epoch_stats() {
    // End-to-end over the wire protocol: with deep pipelining and muf=1
    // the PPT nodes observe staleness; every parameterized node must
    // surface its bucketed histogram through Event::Update into
    // EpochStats::staleness_edges, consistent with the scalar counters.
    let model = mlp_model(1);
    let n_nodes = 4; // 3 linears + loss
    let mut eng = build_engine(EngineKind::Sim, model.graph, BackendSpec::native(), false).unwrap();
    let stats = eng.run_epoch(pumps_for(model.pumper.as_ref(), 6), 6, EpochKind::Train).unwrap();
    assert!(stats.staleness_sum > 0, "pipeline must observe staleness");
    assert!(!stats.staleness_edges.is_empty(), "per-edge histograms missing");
    for (&node, hist) in &stats.staleness_edges {
        assert!(node < n_nodes, "edge key {node} is not a node id");
        assert!(hist.total() > 0);
    }
    let hist_total: u64 = stats.staleness_edges.values().map(|h| h.total()).sum();
    assert_eq!(
        hist_total, stats.staleness_n,
        "histogram mass must equal the applied-contribution count"
    );
    let hist = stats.staleness_hist();
    assert!(hist.0[0] < hist.total(), "some contributions must be stale (muf=1, mak=6)");
}

#[test]
fn prop_random_mak_and_instance_counts_always_retire() {
    ampnet::util::proptest::check("retire_under_random_throttle", |rng| {
        let n = 1 + rng.below_usize(5);
        let mak = 1 + rng.below_usize(8);
        let model = mlp_model(1 + rng.below_usize(300));
        let mut eng =
            build_engine(EngineKind::Sim, model.graph, BackendSpec::native(), false).unwrap();
        let stats = eng
            .run_epoch(pumps_for(model.pumper.as_ref(), n), mak, EpochKind::Train)
            .map_err(|e| format!("{e:#}"))?;
        if stats.instances != n {
            return Err(format!("retired {} of {n}", stats.instances));
        }
        if eng.cached_keys().unwrap() != 0 {
            return Err("leaked keys".into());
        }
        Ok(())
    });
}

fn eval_pumps_for(pumper: &dyn ampnet::models::Pumper, n: usize) -> Vec<PumpSet> {
    (0..n).map(|i| pumper.pump(Split::Valid, i)).collect()
}

#[test]
fn interleaved_eval_losses_exactly_match_drained_eval_baseline() {
    // The §11 correctness oracle. mak=1 makes the sim schedule fully
    // deterministic (one instance in flight, a linear chain), so the two
    // paths must agree BITWISE:
    //   A (pre-refactor semantics): train-only stream, then a separate
    //     drained run_epoch eval;
    //   B (the lane-aware stream): one run_stream whose plan interleaves
    //     the eval epoch, gated on the train lane's close + flush.
    let n_train = 4;
    let n_valid = 2;
    let train_epochs = 2;

    // Path A: drained baseline.
    let model_a = mlp_model(100);
    let mut eng_a =
        build_engine(EngineKind::Sim, model_a.graph, BackendSpec::native(), false).unwrap();
    let epochs_a: Vec<Vec<PumpSet>> =
        (0..train_epochs).map(|_| pumps_for(model_a.pumper.as_ref(), n_train)).collect();
    eng_a.run_stream(StreamPlan::train(epochs_a), &mut FixedMak::new(1)).unwrap();
    let drained = eng_a
        .run_epoch(eval_pumps_for(model_a.pumper.as_ref(), n_valid), 1, EpochKind::Eval)
        .unwrap();

    // Path B: identical model/seed, eval interleaved into the stream.
    let model_b = mlp_model(100);
    let n_nodes = model_b.graph.nodes.len();
    let mut eng_b =
        build_engine(EngineKind::Sim, model_b.graph, BackendSpec::native(), false).unwrap();
    let mut plan = StreamPlan::new();
    for _ in 0..train_epochs {
        plan.push(Lane::Train, pumps_for(model_b.pumper.as_ref(), n_train));
    }
    plan.push(Lane::Eval, eval_pumps_for(model_b.pumper.as_ref(), n_valid));
    let stats = eng_b.run_stream(plan, &mut FixedMak::new(1)).unwrap();
    assert_eq!(stats.len(), train_epochs + 1);
    let interleaved = stats.last().unwrap();
    assert_eq!(interleaved.lane, Lane::Eval);

    // The training halves were identical, so the parameters the eval
    // lane observed are bitwise the drained baseline's ...
    for node in 0..n_nodes {
        assert_eq!(
            eng_a.params_of(node).unwrap(),
            eng_b.params_of(node).unwrap(),
            "node {node}: params diverged between the two paths"
        );
    }
    // ... and therefore so are the validation numbers. EXACT equality,
    // not approximate: the oracle is bit-level.
    assert_eq!(interleaved.instances, drained.instances);
    assert_eq!(interleaved.loss_events, drained.loss_events);
    assert_eq!(interleaved.correct, drained.correct);
    assert_eq!(interleaved.count, drained.count);
    assert_eq!(
        interleaved.loss_sum.to_bits(),
        drained.loss_sum.to_bits(),
        "interleaved eval loss {} != drained baseline {}",
        interleaved.loss_sum,
        drained.loss_sum
    );
    assert!(interleaved.closed_at > 0.0, "eval watermark closed inside the stream");
    assert_eq!(eng_b.cached_keys().unwrap(), 0);
}

fn opt_states(eng: &mut dyn Engine, n_nodes: usize) -> Vec<Option<OptState>> {
    (0..n_nodes).map(|n| eng.opt_state_of(n).unwrap()).collect()
}

fn assert_opt_states_eq(a: &[Option<OptState>], b: &[Option<OptState>]) {
    assert_eq!(a.len(), b.len());
    for (n, (x, y)) in a.iter().zip(b).enumerate() {
        match (x, y) {
            (None, None) => {}
            (Some(x), Some(y)) => {
                assert_eq!(x.grads, y.grads, "node {n}: gradient accumulator changed");
                assert_eq!(x.m, y.m, "node {n}: Adam m changed");
                assert_eq!(x.v, y.v, "node {n}: Adam v changed");
                assert_eq!(x.pending, y.pending, "node {n}: pending changed");
                assert_eq!(x.updates, y.updates, "node {n}: update counter changed");
                assert_eq!(x.step, y.step, "node {n}: step changed");
            }
            _ => panic!("node {n}: optimizer state appeared/disappeared"),
        }
    }
}

#[test]
fn eval_lane_never_mutates_params_or_optimizer_state() {
    // Warm up with one training epoch (so optimizer state is nontrivial),
    // then stream TWO eval epochs — same valid ids in both, exercising
    // duplicate-id deferral inside the eval lane — and require parameters
    // AND optimizer state to be bit-identical afterwards.
    for engine_kind in [EngineKind::Sim, EngineKind::Threaded] {
        let model = mlp_model(100);
        let n_nodes = model.graph.nodes.len();
        let mut eng =
            build_engine(engine_kind, model.graph, BackendSpec::native(), false).unwrap();
        eng.run_epoch(pumps_for(model.pumper.as_ref(), 4), 2, EpochKind::Train).unwrap();
        let params_before: Vec<_> = (0..n_nodes).map(|n| eng.params_of(n).unwrap()).collect();
        let opt_before = opt_states(eng.as_mut(), n_nodes);
        let evals: Vec<Vec<PumpSet>> =
            (0..2).map(|_| eval_pumps_for(model.pumper.as_ref(), 2)).collect();
        let stats = eng
            .run_stream(StreamPlan::uniform(Lane::Eval, evals), &mut FixedMak::new(4))
            .unwrap_or_else(|e| panic!("{engine_kind}: {e:#}"));
        for (e, s) in stats.iter().enumerate() {
            assert_eq!(s.lane, Lane::Eval, "{engine_kind}");
            assert_eq!(s.instances, 2, "{engine_kind}: eval epoch {e} retire count");
            assert_eq!(s.updates, 0, "{engine_kind}: eval must not update");
        }
        for (n, want) in params_before.iter().enumerate() {
            assert_eq!(
                &eng.params_of(n).unwrap(),
                want,
                "{engine_kind}: node {n} params changed during eval"
            );
        }
        assert_opt_states_eq(&opt_before, &opt_states(eng.as_mut(), n_nodes));
        assert_eq!(eng.cached_keys().unwrap(), 0, "{engine_kind} leaked");
    }
}

#[test]
fn per_lane_watermarks_separate_under_duplicate_ids() {
    // Two pipelined train epochs share the SAME instance ids (duplicate
    // deferral across epochs) while a live eval epoch rides the stream in
    // its disjoint id range; every epoch must see exactly its own
    // population, on both engines.
    let n = 4;
    for engine_kind in [EngineKind::Sim, EngineKind::Threaded] {
        let model = mlp_model(100);
        let mut eng =
            build_engine(engine_kind, model.graph, BackendSpec::native(), false).unwrap();
        let mut plan = StreamPlan::new();
        plan.push(Lane::Train, pumps_for(model.pumper.as_ref(), n));
        plan.push(Lane::Train, pumps_for(model.pumper.as_ref(), n));
        plan.push(Lane::Eval, eval_pumps_for(model.pumper.as_ref(), 2));
        let stats = eng
            .run_stream(plan.live(), &mut FixedMak::new(4))
            .unwrap_or_else(|e| panic!("{engine_kind}: {e:#}"));
        assert_eq!(stats.len(), 3, "{engine_kind}");
        assert_eq!(stats[0].lane, Lane::Train);
        assert_eq!(stats[1].lane, Lane::Train);
        assert_eq!(stats[2].lane, Lane::Eval);
        assert_eq!(stats[0].instances, n, "{engine_kind}: train epoch 0");
        assert_eq!(stats[1].instances, n, "{engine_kind}: train epoch 1");
        assert_eq!(stats[2].instances, 2, "{engine_kind}: eval epoch");
        assert_eq!(stats[2].loss_events, 2, "{engine_kind}: eval losses on the eval lane");
        assert_eq!(
            stats[0].loss_events + stats[1].loss_events,
            2 * n,
            "{engine_kind}: train losses stay on the train lane"
        );
        assert!(stats[2].closed_at > 0.0, "{engine_kind}: eval watermark closed");
        assert_eq!(eng.cached_keys().unwrap(), 0, "{engine_kind} leaked");
    }
}

/// Captures what the controller surfaces to admission policies.
struct ProbePolicy {
    window: usize,
    hop_depth: u32,
    backlog_max: usize,
    eval_retires: usize,
    train_retires: usize,
}

impl AdmissionPolicy for ProbePolicy {
    fn name(&self) -> &'static str {
        "probe"
    }
    fn window(&self) -> usize {
        self.window
    }
    fn on_retire(&mut self, obs: &ControlObs) {
        self.hop_depth = self.hop_depth.max(obs.hop_depth);
        self.backlog_max = self.backlog_max.max(obs.backlog);
        match obs.lane {
            Lane::Eval => self.eval_retires += 1,
            Lane::Train => self.train_retires += 1,
        }
    }
}

#[test]
fn hop_counts_estimate_pipeline_depth_end_to_end() {
    // MLP chain: x -> L1 -> L2 -> L3 -> loss -> bwd(L3, L2, L1) ->
    // controller = 7 runtime emissions. The hop tag (merge max+1 per
    // emission) must surface exactly that through ControlObs on both
    // engines — no model knowledge involved.
    for engine_kind in [EngineKind::Sim, EngineKind::Threaded] {
        let model = mlp_model(100);
        let mut eng =
            build_engine(engine_kind, model.graph, BackendSpec::native(), false).unwrap();
        let mut probe = ProbePolicy {
            window: 6,
            hop_depth: 0,
            backlog_max: 0,
            eval_retires: 0,
            train_retires: 0,
        };
        let epochs = vec![pumps_for(model.pumper.as_ref(), 6)];
        eng.run_stream(StreamPlan::train(epochs), &mut probe)
            .unwrap_or_else(|e| panic!("{engine_kind}: {e:#}"));
        assert_eq!(
            probe.hop_depth, 7,
            "{engine_kind}: hop depth should be 2*depth+1 for the 3-layer MLP"
        );
        assert_eq!(probe.train_retires, 6, "{engine_kind}");
    }
}

#[test]
fn queue_backlog_reaches_admission_policy_in_sim() {
    // Deep pipeline (mak=6): at some retire the sim's worker queues must
    // be non-empty, and the controller reports that depth to the policy.
    let model = mlp_model(100);
    let mut eng = build_engine(EngineKind::Sim, model.graph, BackendSpec::native(), false).unwrap();
    let mut probe = ProbePolicy {
        window: 6,
        hop_depth: 0,
        backlog_max: 0,
        eval_retires: 0,
        train_retires: 0,
    };
    let epochs = vec![pumps_for(model.pumper.as_ref(), 6)];
    eng.run_stream(StreamPlan::train(epochs), &mut probe).unwrap();
    assert!(
        probe.backlog_max > 0,
        "expected a non-empty queue backlog observation with 6 instances in flight"
    );
}

#[test]
fn per_epoch_trace_attribution_follows_watermarks() {
    // Satellite: trace segments ship at watermark closes, so a
    // multi-epoch stream attributes Gantt entries per epoch instead of
    // dumping the run total on the last epoch. Totals must be conserved
    // on both engines; the sim's virtual-time cuts are exact, so there
    // every epoch is additionally guaranteed its own non-empty segment
    // (the threaded engine's worker-side marks are best-effort at the
    // boundary — a racing tail can land in the neighboring epoch).
    for engine_kind in [EngineKind::Sim, EngineKind::Threaded] {
        let model = mlp_model(100);
        let mut eng =
            build_engine(engine_kind, model.graph, BackendSpec::native(), true).unwrap();
        let epochs: Vec<Vec<PumpSet>> =
            (0..3).map(|_| pumps_for(model.pumper.as_ref(), 4)).collect();
        let stats = eng
            .run_stream(StreamPlan::train(epochs), &mut FixedMak::new(2))
            .unwrap_or_else(|e| panic!("{engine_kind}: {e:#}"));
        let mut total = 0usize;
        for (e, s) in stats.iter().enumerate() {
            if engine_kind == EngineKind::Sim {
                assert!(!s.trace.is_empty(), "sim: epoch {e} has no trace entries");
            }
            assert_eq!(
                s.trace.is_empty(),
                s.node_labels.is_empty(),
                "{engine_kind}: epoch {e} trace/labels out of sync"
            );
            total += s.trace.len();
        }
        assert!(
            !stats[0].trace.is_empty(),
            "{engine_kind}: the first epoch always owns its own segment"
        );
        // 4 instances/epoch x 8 invocations each (L1/L2/L3/loss-label/
        // loss-pred forward + L3/L2/L1 backward) = 32 per epoch
        assert_eq!(total, 3 * 32, "{engine_kind}: trace entries lost or duplicated");
    }
}
