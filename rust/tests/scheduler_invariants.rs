//! Engine-level invariants, checked on both the simulator and the
//! threaded runtime with the native backend (no artifacts needed):
//!
//! * every pumped message retires (fwd/bwd state invariant, §4);
//! * no cached keys survive an epoch (leak freedom);
//! * max_active_keys truly bounds in-flight instances;
//! * with one flush-time update, both engines and any mak produce
//!   *identical* parameters (gradient accumulation is order-independent);
//! * randomized pipeline property: arbitrary interleavings retire.

use ampnet::data::{MnistLike, Split};
use ampnet::ir::PumpSet;
use ampnet::models::{mlp, rnn, ModelCfg};
use ampnet::runtime::BackendSpec;
use ampnet::scheduler::{
    build_engine, AdmissionKind, Engine, EngineKind, EpochKind, EpochStats, StalenessKind,
};
use ampnet::tensor::ops::rel_diff;

fn mlp_model(muf: usize) -> ampnet::models::BuiltModel {
    let mut cfg = ModelCfg::default();
    cfg.muf = muf;
    mlp::build(&cfg, MnistLike::new(0, 600, 200, 100), 4).unwrap()
}

fn pumps_for(pumper: &dyn ampnet::models::Pumper, n: usize) -> Vec<PumpSet> {
    (0..n).map(|i| pumper.pump(Split::Train, i)).collect()
}

#[test]
fn both_engines_retire_and_do_not_leak() {
    for engine_kind in [EngineKind::Sim, EngineKind::Threaded] {
        let model = mlp_model(100);
        let mut eng =
            build_engine(engine_kind, model.graph, BackendSpec::native(), false).unwrap();
        let stats = eng
            .run_epoch(pumps_for(model.pumper.as_ref(), 6), 3, EpochKind::Train)
            .unwrap_or_else(|e| panic!("{engine_kind}: {e:#}"));
        assert_eq!(stats.instances, 6, "{engine_kind}");
        assert_eq!(stats.loss_events, 6, "{engine_kind}");
        assert!(stats.updates > 0, "{engine_kind}");
        assert_eq!(eng.cached_keys().unwrap(), 0, "{engine_kind} leaked");
    }
}

#[test]
fn engines_agree_bitwise_when_updates_are_deferred() {
    // One update at flush time => gradient sum is message-order-invariant
    // => sim and threaded (any mak) give identical parameters.
    let collect = |engine_kind: EngineKind, mak: usize| -> Vec<ampnet::tensor::Tensor> {
        let model = mlp_model(1_000_000_000);
        let n_nodes = model.graph.nodes.len();
        let mut eng =
            build_engine(engine_kind, model.graph, BackendSpec::native(), false).unwrap();
        eng.run_epoch(pumps_for(model.pumper.as_ref(), 4), mak, EpochKind::Train).unwrap();
        let mut out = Vec::new();
        for node in 0..n_nodes {
            out.extend(eng.params_of(node).unwrap());
        }
        out
    };
    let a = collect(EngineKind::Sim, 1);
    let b = collect(EngineKind::Sim, 4);
    let c = collect(EngineKind::Threaded, 4);
    assert_eq!(a.len(), b.len());
    for ((x, y), z) in a.iter().zip(&b).zip(&c) {
        assert!(rel_diff(x, y) < 1e-6, "sim mak1 vs mak4");
        assert!(rel_diff(x, z) < 1e-6, "sim vs threaded");
    }
}

#[test]
fn mak_bounds_inflight_instances() {
    // Indirect check through the controller: a mak=1 run must show
    // strictly serialized losses == instances, and staleness 0 for MLP.
    let model = mlp_model(100);
    let mut eng = build_engine(EngineKind::Sim, model.graph, BackendSpec::native(), false).unwrap();
    let stats = eng.run_epoch(pumps_for(model.pumper.as_ref(), 5), 1, EpochKind::Train).unwrap();
    assert_eq!(stats.instances, 5);
    assert_eq!(
        stats.mean_staleness(),
        0.0,
        "synchronous MLP cannot see stale gradients"
    );
}

#[test]
fn async_runs_exhibit_staleness_on_deep_pipelines() {
    // With many instances in flight and muf=1 updates, some backward
    // passes must observe parameter updates that happened since their
    // forward pass.
    let model = mlp_model(1);
    let mut eng = build_engine(EngineKind::Sim, model.graph, BackendSpec::native(), false).unwrap();
    let stats = eng.run_epoch(pumps_for(model.pumper.as_ref(), 6), 6, EpochKind::Train).unwrap();
    assert!(
        stats.staleness_sum > 0,
        "expected nonzero staleness with mak=6, muf=1"
    );
}

#[test]
fn batched_inbox_preserves_backward_priority() {
    // The threaded engine's inbox drains in batches: one lock swap can
    // deliver forward and backward messages mixed together. Backward
    // priority must survive that. With every node on ONE worker the
    // trace is a serial schedule, and strict backward-first processing
    // implies each backward chain, once initiated by the loss, runs to
    // completion before any queued forward resumes: every maximal run of
    // backward entries must be exactly 3 long (the MLP's three linear
    // layers), one run per instance.
    let mut cfg = ModelCfg::default();
    cfg.muf = 100;
    let model = mlp::build(&cfg, MnistLike::new(0, 600, 200, 100), 1).unwrap();
    let n = 6;
    let mut eng =
        build_engine(EngineKind::Threaded, model.graph, BackendSpec::native(), true).unwrap();
    let stats = eng
        .run_epoch(pumps_for(model.pumper.as_ref(), n), n, EpochKind::Train)
        .unwrap();
    assert_eq!(stats.instances, n);
    assert!(!stats.trace.is_empty(), "tracing was enabled");
    assert!(
        !stats.node_labels.is_empty(),
        "labels are resolved once at flush time"
    );
    assert!(
        stats.trace.iter().all(|e| e.worker == 0),
        "single-worker schedule expected"
    );
    let mut runs: Vec<usize> = Vec::new();
    let mut cur = 0usize;
    for e in &stats.trace {
        if e.backward {
            cur += 1;
        } else if cur > 0 {
            runs.push(cur);
            cur = 0;
        }
    }
    if cur > 0 {
        runs.push(cur);
    }
    assert_eq!(runs.len(), n, "one backward chain per instance: {runs:?}");
    assert!(
        runs.iter().all(|&r| r == 3),
        "a forward ran while backward messages were queued: {runs:?}"
    );
    assert_eq!(eng.cached_keys().unwrap(), 0);
}

#[test]
fn rnn_loop_retires_in_threaded_engine() {
    let data = ampnet::data::ListRedGen::new(0, 300, 100, 100);
    let model = rnn::build(&ModelCfg::default(), data, 8, 2).unwrap();
    let mut eng =
        build_engine(EngineKind::Threaded, model.graph, BackendSpec::native(), false).unwrap();
    let pumps: Vec<PumpSet> =
        (0..3).map(|i| model.pumper.pump(Split::Train, i)).collect();
    let stats = eng.run_epoch(pumps, 4, EpochKind::Train).unwrap();
    assert_eq!(stats.instances, 3);
    assert_eq!(eng.cached_keys().unwrap(), 0);
    // params can be fetched and written back across threads
    ampnet::scheduler::sync_replicas(eng.as_mut(), &model.replica_groups).unwrap();
}

#[test]
fn streaming_admission_retires_every_instance_exactly_once_per_epoch() {
    // Three epochs pipelined through one run_stream call: instances of
    // epoch e+1 are admitted while epoch e's tail retires, yet each
    // epoch's watermark accounting must see exactly its own population.
    let n = 6;
    for engine_kind in [EngineKind::Sim, EngineKind::Threaded] {
        let model = mlp_model(100);
        let mut eng =
            build_engine(engine_kind, model.graph, BackendSpec::native(), false).unwrap();
        let epochs: Vec<Vec<PumpSet>> =
            (0..3).map(|_| pumps_for(model.pumper.as_ref(), n)).collect();
        let mut admission = AdmissionKind::Fixed.policy(4);
        let stats = eng
            .run_stream(epochs, admission.as_mut(), EpochKind::Train)
            .unwrap_or_else(|e| panic!("{engine_kind}: {e:#}"));
        assert_eq!(stats.len(), 3, "{engine_kind}: one stats entry per epoch");
        for (e, s) in stats.iter().enumerate() {
            assert_eq!(s.instances, n, "{engine_kind}: epoch {e} retire count");
            assert_eq!(s.loss_events, n, "{engine_kind}: epoch {e} loss events");
        }
        assert_eq!(eng.cached_keys().unwrap(), 0, "{engine_kind} leaked");
    }
}

#[test]
fn aimd_never_exceeds_its_ceiling() {
    // Generous staleness bound => pure additive increase; the in-flight
    // population must still never cross the configured ceiling.
    let ceiling = 3;
    let model = mlp_model(1);
    let mut eng = build_engine(EngineKind::Sim, model.graph, BackendSpec::native(), false).unwrap();
    let epochs: Vec<Vec<PumpSet>> =
        (0..4).map(|_| pumps_for(model.pumper.as_ref(), 6)).collect();
    let mut admission = AdmissionKind::Aimd { staleness_bound: 1e9 }.policy(ceiling);
    let stats = eng.run_stream(epochs, admission.as_mut(), EpochKind::Train).unwrap();
    let total: usize = stats.iter().map(|s| s.instances).sum();
    assert_eq!(total, 24);
    for (e, s) in stats.iter().enumerate() {
        assert!(
            s.max_active <= ceiling,
            "epoch {e}: {} instances in flight above ceiling {ceiling}",
            s.max_active
        );
    }
    assert!(
        stats.iter().any(|s| s.max_active == ceiling),
        "additive increase should reach the ceiling"
    );
    assert_eq!(eng.cached_keys().unwrap(), 0);
}

#[test]
fn clip_policy_bounds_applied_staleness_under_batched_drains() {
    // Threaded engine: BatchQueue delivers mixed fwd/bwd batches and
    // muf=1 updates fire on every backward, so staleness is rampant.
    // With `clip:1` the *applied* staleness must stay within the bound
    // and over-stale contributions must be counted as dropped.
    let mut cfg = ModelCfg::default();
    cfg.muf = 1;
    cfg.staleness = StalenessKind::Clip { max_staleness: 1 };
    let model = mlp::build(&cfg, MnistLike::new(0, 800, 200, 100), 4).unwrap();
    let mut eng =
        build_engine(EngineKind::Threaded, model.graph, BackendSpec::native(), false).unwrap();
    let epochs: Vec<Vec<PumpSet>> =
        (0..3).map(|_| pumps_for(model.pumper.as_ref(), 8)).collect();
    let mut admission = AdmissionKind::Fixed.policy(8);
    let stats = eng.run_stream(epochs, admission.as_mut(), EpochKind::Train).unwrap();
    let smax = stats.iter().map(|s| s.staleness_max).max().unwrap();
    assert!(smax <= 1, "applied staleness {smax} exceeds the clip bound");
    let total: usize = stats.iter().map(|s| s.instances).sum();
    assert_eq!(total, 24, "dropping gradients must not affect retirement");
    assert_eq!(eng.cached_keys().unwrap(), 0);
}

#[test]
fn aimd_streaming_sustains_higher_occupancy_than_fixed_mak_drains() {
    // The acceptance experiment: at an equal MAK ceiling, AdaptiveAimd +
    // LrDiscount driving a cross-epoch stream must sustain higher mean
    // occupancy than the classic FixedMak cycle that drains the pipeline
    // to zero at every epoch boundary — while the staleness the AIMD
    // controller admits stays within its configured bound.
    let ceiling = 4;
    let n = 10;
    let n_epochs = 8;
    let bound = 6.0;
    let agg = |stats: &[EpochStats]| -> (f64, f64) {
        let m = EpochStats::merged(stats);
        (m.mean_occupancy(), m.mean_staleness())
    };

    // Path A: today's semantics — FixedMak, drain-to-zero per epoch.
    let fixed_stats: Vec<EpochStats> = {
        let model = mlp_model(1);
        let mut eng =
            build_engine(EngineKind::Sim, model.graph, BackendSpec::native(), false).unwrap();
        (0..n_epochs)
            .map(|_| {
                eng.run_epoch(pumps_for(model.pumper.as_ref(), n), ceiling, EpochKind::Train)
                    .unwrap()
            })
            .collect()
    };
    // Path B: the new control plane — AIMD admission over one stream,
    // LrDiscount staleness policy in every ParamSet.
    let aimd_stats: Vec<EpochStats> = {
        let mut cfg = ModelCfg::default();
        cfg.muf = 1;
        cfg.staleness = StalenessKind::LrDiscount { alpha: 0.5 };
        let model = mlp::build(&cfg, MnistLike::new(0, 600, 200, 100), 4).unwrap();
        let mut eng =
            build_engine(EngineKind::Sim, model.graph, BackendSpec::native(), false).unwrap();
        let epochs: Vec<Vec<PumpSet>> =
            (0..n_epochs).map(|_| pumps_for(model.pumper.as_ref(), n)).collect();
        let mut admission = AdmissionKind::Aimd { staleness_bound: bound }.policy(ceiling);
        eng.run_stream(epochs, admission.as_mut(), EpochKind::Train).unwrap()
    };
    let (fixed_occ, _) = agg(&fixed_stats);
    let (aimd_occ, aimd_stale) = agg(&aimd_stats);
    assert!(
        aimd_occ > fixed_occ,
        "streaming AIMD occupancy {aimd_occ:.3} should beat drain-per-epoch FixedMak {fixed_occ:.3} \
         at equal ceiling {ceiling}"
    );
    assert!(
        aimd_stale <= bound,
        "mean applied staleness {aimd_stale:.3} exceeds the configured bound {bound}"
    );
    let total: usize = aimd_stats.iter().map(|s| s.instances).sum();
    assert_eq!(total, n_epochs * n);
}

#[test]
fn streaming_attributes_busy_seconds_to_each_epoch() {
    // Satellite of ISSUE 4: worker busy counters are snapshotted at
    // watermark closes, so per-epoch utilization no longer collapses
    // onto the stream's last epoch.
    let n = 6;
    for engine_kind in [EngineKind::Sim, EngineKind::Threaded] {
        let model = mlp_model(100);
        let mut eng =
            build_engine(engine_kind, model.graph, BackendSpec::native(), false).unwrap();
        let epochs: Vec<Vec<PumpSet>> =
            (0..3).map(|_| pumps_for(model.pumper.as_ref(), n)).collect();
        let mut admission = AdmissionKind::Fixed.policy(2);
        let stats = eng.run_stream(epochs, admission.as_mut(), EpochKind::Train).unwrap();
        for (e, s) in stats.iter().enumerate() {
            let busy: f64 = s.worker_busy.iter().sum();
            assert!(
                busy > 0.0,
                "{engine_kind}: epoch {e} attributed no busy time (worker_busy {:?})",
                s.worker_busy
            );
        }
        // totals must be conserved: per-epoch shares sum to the run total
        let per_epoch: f64 =
            stats.iter().map(|s| s.worker_busy.iter().sum::<f64>()).sum();
        assert!(per_epoch > 0.0);
        // each epoch processed work, so messages attribute per epoch too
        for (e, s) in stats.iter().enumerate() {
            assert!(s.messages > 0, "{engine_kind}: epoch {e} shows zero messages");
        }
    }
}

#[test]
fn per_edge_staleness_histograms_reach_epoch_stats() {
    // End-to-end over the wire protocol: with deep pipelining and muf=1
    // the PPT nodes observe staleness; every parameterized node must
    // surface its bucketed histogram through Event::Update into
    // EpochStats::staleness_edges, consistent with the scalar counters.
    let model = mlp_model(1);
    let n_nodes = 4; // 3 linears + loss
    let mut eng = build_engine(EngineKind::Sim, model.graph, BackendSpec::native(), false).unwrap();
    let stats = eng.run_epoch(pumps_for(model.pumper.as_ref(), 6), 6, EpochKind::Train).unwrap();
    assert!(stats.staleness_sum > 0, "pipeline must observe staleness");
    assert!(!stats.staleness_edges.is_empty(), "per-edge histograms missing");
    for (&node, hist) in &stats.staleness_edges {
        assert!(node < n_nodes, "edge key {node} is not a node id");
        assert!(hist.total() > 0);
    }
    let hist_total: u64 = stats.staleness_edges.values().map(|h| h.total()).sum();
    assert_eq!(
        hist_total, stats.staleness_n,
        "histogram mass must equal the applied-contribution count"
    );
    let hist = stats.staleness_hist();
    assert!(hist.0[0] < hist.total(), "some contributions must be stale (muf=1, mak=6)");
}

#[test]
fn prop_random_mak_and_instance_counts_always_retire() {
    ampnet::util::proptest::check("retire_under_random_throttle", |rng| {
        let n = 1 + rng.below_usize(5);
        let mak = 1 + rng.below_usize(8);
        let model = mlp_model(1 + rng.below_usize(300));
        let mut eng =
            build_engine(EngineKind::Sim, model.graph, BackendSpec::native(), false).unwrap();
        let stats = eng
            .run_epoch(pumps_for(model.pumper.as_ref(), n), mak, EpochKind::Train)
            .map_err(|e| format!("{e:#}"))?;
        if stats.instances != n {
            return Err(format!("retired {} of {n}", stats.instances));
        }
        if eng.cached_keys().unwrap() != 0 {
            return Err("leaked keys".into());
        }
        Ok(())
    });
}
