//! Wire-format properties (DESIGN.md §12): arbitrary `Message` frames
//! round-trip bit-exactly, every control envelope survives
//! encode→decode, corrupt bytes are rejected rather than misparsed, and
//! the decoder draws tensor payloads from the size-class pool — the
//! zero-copy discipline survives serialization.

use ampnet::ir::{Dir, Event, Message, MsgMeta, MsgState};
use ampnet::optim::{OptState, StalenessStats};
use ampnet::prop_assert;
use ampnet::scheduler::{StaleHist, TraceEntry};
use ampnet::tensor::{pool, Tensor};
use ampnet::transport::wire::{decode_frame, encode_frame, HEADER_LEN};
use ampnet::transport::{Frame, Hello, ParamEntry, WIRE_VERSION};
use ampnet::util::proptest::check;
use ampnet::util::Pcg32;

fn arbitrary_message(rng: &mut Pcg32) -> Message {
    let state = MsgState {
        instance: rng.next_u64(),
        replica: rng.next_u32() as u16,
        t: rng.next_u32(),
        t_max: rng.next_u32(),
        node: rng.next_u32(),
        edge: rng.next_u32(),
        etype: rng.next_u32() as u8,
        aux: rng.next_u32(),
    };
    let dir = if rng.below(2) == 0 { Dir::Fwd } else { Dir::Bwd };
    let meta = MsgMeta {
        train: rng.below(2) == 0,
        param_version: if rng.below(2) == 0 { Some(rng.next_u64()) } else { None },
        hops: rng.next_u32(),
    };
    let payload = (0..rng.below_usize(4))
        .map(|_| {
            let dims: Vec<usize> =
                (0..1 + rng.below_usize(2)).map(|_| 1 + rng.below_usize(8)).collect();
            let n: usize = dims.iter().product();
            // raw bit patterns: exercises NaNs, infinities, subnormals
            let data: Vec<f32> = (0..n).map(|_| f32::from_bits(rng.next_u32())).collect();
            Tensor::new(dims, data)
        })
        .collect();
    Message { dir, state, payload, meta }
}

fn messages_equal(a: &Message, b: &Message) -> Result<(), String> {
    prop_assert!(a.dir == b.dir, "dir changed");
    prop_assert!(a.state == b.state, "state changed: {:?} vs {:?}", a.state, b.state);
    prop_assert!(a.meta == b.meta, "meta changed: {:?} vs {:?}", a.meta, b.meta);
    prop_assert!(a.payload.len() == b.payload.len(), "payload count changed");
    for (i, (x, y)) in a.payload.iter().zip(&b.payload).enumerate() {
        prop_assert!(x.shape() == y.shape(), "tensor {i} shape changed");
        let bits_equal = x.data().iter().zip(y.data()).all(|(u, v)| u.to_bits() == v.to_bits());
        prop_assert!(bits_equal, "tensor {i} payload bits changed");
    }
    Ok(())
}

fn roundtrip(frame: &Frame) -> Frame {
    let mut buf = Vec::new();
    encode_frame(frame, &mut buf);
    let (decoded, used) = decode_frame(&buf).expect("decode");
    assert_eq!(used, buf.len(), "decoder must consume the whole frame");
    decoded
}

#[test]
fn deliver_frames_roundtrip_bit_exactly() {
    check("wire_deliver_roundtrip", |rng| {
        let msg = arbitrary_message(rng);
        let node = rng.next_u32();
        let port = rng.below(4);
        let mut buf = Vec::new();
        encode_frame(&Frame::Deliver { node, port, msg: msg.clone() }, &mut buf);
        prop_assert!(buf[0] == WIRE_VERSION, "first byte is the version");
        let (decoded, used) = decode_frame(&buf).map_err(|e| e.to_string())?;
        prop_assert!(used == buf.len(), "consumed {used} of {} bytes", buf.len());
        match decoded {
            Frame::Deliver { node: n2, port: p2, msg: m2 } => {
                prop_assert!(n2 == node && p2 == port, "envelope fields changed");
                messages_equal(&msg, &m2)
            }
            other => Err(format!("decoded to a different frame kind: {other:?}")),
        }
    });
}

#[test]
fn every_control_envelope_roundtrips() {
    let mut stale = StalenessStats {
        sum: 9,
        n: 3,
        max: 5,
        dropped: 1,
        hist: StaleHist::default(),
    };
    stale.hist.note(0);
    stale.hist.note(4);
    stale.hist.note(5);
    let frames = vec![
        Frame::Hello(Hello {
            model: "mlp".into(),
            args: "--seed 42 --lr 0.1".into(),
            workers: 8,
            n_shards: 2,
            shard: 1,
            scale: 0.002,
            backend: "native".into(),
            trace: true,
            heartbeat_ms: 250,
            fingerprint: 0xdead_beef_cafe_f00d,
            peer_listen: "uds:/tmp/w1.sock.peer".into(),
            peers: vec!["uds:/tmp/w0.sock.peer".into(), "uds:/tmp/w1.sock.peer".into()],
            fault_plan: "kill:link=0-1@step=2;seed=9".into(),
        }),
        Frame::HelloAck { fingerprint: 0xdead_beef_cafe_f00d, nodes: 7 },
        Frame::Retire { instance: u64::MAX, hops: 12 },
        Frame::Event(Event::Loss {
            instance: 3,
            loss: f32::NAN,
            correct: 1,
            count: 2,
            abs_err: 0.25,
            train: false,
        }),
        Frame::Event(Event::Update { node: 4, staleness: stale }),
        Frame::Event(Event::EvalDone { instance: 11 }),
        Frame::EpochStart,
        Frame::EpochMark { epoch: 3 },
        Frame::BusyMark {
            epoch: 2,
            busy: vec![(0, 0.5), (3, 1.25)],
            processed: [40, 9],
            backlog: 6,
            trace: vec![TraceEntry {
                worker: 1,
                node: 2,
                instance: 5,
                backward: true,
                start: 0.1,
                end: 0.2,
            }],
        },
        Frame::FlushParams,
        Frame::FlushParamsAck,
        Frame::Flush,
        Frame::FlushReply { busy: vec![(1, 2.0)], processed: [7, 0], trace: vec![] },
        Frame::GetParams { node: 9 },
        Frame::Params { node: 9, params: vec![Tensor::zeros(&[2, 3]), Tensor::zeros(&[3])] },
        Frame::SetParams { node: 9, params: vec![Tensor::zeros(&[4])] },
        Frame::SetParamsAck { node: 9 },
        Frame::GetOptState { node: 1 },
        Frame::OptStateReply { node: 1, state: None },
        Frame::OptStateReply {
            node: 1,
            state: Some(OptState {
                grads: vec![Tensor::zeros(&[2, 2])],
                m: vec![Some(Tensor::zeros(&[2, 2]))],
                v: vec![None],
                pending: 3,
                updates: 17,
                step: 5,
            }),
        },
        Frame::SetOptState {
            node: 2,
            state: OptState {
                grads: vec![],
                m: vec![],
                v: vec![],
                pending: 0,
                updates: 1,
                step: 1,
            },
        },
        Frame::SetOptStateAck { node: 2, err: Some("no params".into()) },
        Frame::SetOptStateAck { node: 2, err: None },
        Frame::CachedKeys,
        Frame::CachedKeysReply { n: 123 },
        Frame::Heartbeat { backlog: 42 },
        Frame::Shutdown,
        Frame::Abort { msg: "node 'loss': boom".into() },
        Frame::GetParamsBatch { nodes: vec![] },
        Frame::GetParamsBatch { nodes: vec![0, 3, 7] },
        Frame::ParamsBatch { entries: vec![] },
        Frame::ParamsBatch {
            entries: vec![
                // unparameterized node: empty params, no opt state
                ParamEntry { node: 0, params: vec![], state: None },
                ParamEntry {
                    node: 3,
                    params: vec![Tensor::zeros(&[2, 3]), Tensor::zeros(&[3])],
                    state: Some(OptState {
                        grads: vec![Tensor::zeros(&[2, 3])],
                        m: vec![Some(Tensor::zeros(&[2, 3]))],
                        v: vec![None],
                        pending: 1,
                        updates: 9,
                        step: 4,
                    }),
                },
            ],
        },
        Frame::SetParamsBatch {
            entries: vec![ParamEntry {
                node: 5,
                params: vec![Tensor::zeros(&[4])],
                state: None,
            }],
        },
        Frame::SetParamsBatchAck { n: 2, err: None },
        Frame::SetParamsBatchAck { n: 2, err: Some("node 3: shape".into()) },
        Frame::PeerHello { from: 3 },
        Frame::PeerDrain { token: u64::MAX },
        Frame::PeerDrainAck { token: 7, sent: vec![0, 12, 3], recv: vec![5, 0, 9] },
        Frame::PeerDrainAck { token: 8, sent: vec![], recv: vec![] },
    ];
    for frame in &frames {
        let decoded = roundtrip(frame);
        // Frame holds tensors, so there is no PartialEq; the Debug
        // rendering covers every scalar field and tensor shape/value.
        assert_eq!(format!("{decoded:?}"), format!("{frame:?}"));
    }
}

#[test]
fn corrupt_and_truncated_frames_are_rejected() {
    let mut buf = Vec::new();
    encode_frame(&Frame::Heartbeat { backlog: 7 }, &mut buf);

    // wrong wire version
    let mut bad = buf.clone();
    bad[0] = WIRE_VERSION.wrapping_add(1);
    assert!(decode_frame(&bad).is_err(), "future version must be rejected");

    // unknown frame kind
    let mut bad = buf.clone();
    bad[1] = 0xfe;
    assert!(decode_frame(&bad).is_err(), "unknown kind must be rejected");

    // every possible truncation point
    for k in 0..buf.len() {
        assert!(decode_frame(&buf[..k]).is_err(), "truncation at {k} must be rejected");
    }

    // trailing garbage inside the declared body length
    let msg = Message::fwd(MsgState::for_instance(1), vec![Tensor::zeros(&[2, 2])]);
    let mut buf = Vec::new();
    encode_frame(&Frame::Deliver { node: 0, port: 0, msg }, &mut buf);
    let body_len = (buf.len() - HEADER_LEN) as u32 + 4;
    buf[2..6].copy_from_slice(&body_len.to_le_bytes());
    buf.extend_from_slice(&[0u8; 4]);
    assert!(decode_frame(&buf).is_err(), "padded body must be rejected");
}

#[test]
fn decode_reuses_pooled_buffers() {
    // The pooled-decode self-check from the issue: decode repeatedly on
    // one thread (the pool is thread-local); decoded tensors draw their
    // backing stores from pool::take and return them on drop, so after
    // the first iteration allocations are pool hits.
    let msg = Message::fwd(
        MsgState::for_instance(7),
        vec![Tensor::zeros(&[32, 16]), Tensor::zeros(&[64])],
    );
    let frame = Frame::Deliver { node: 3, port: 0, msg };
    let mut buf = Vec::new();
    encode_frame(&frame, &mut buf);
    pool::clear();
    for _ in 0..32 {
        let (decoded, _) = decode_frame(&buf).expect("decode");
        drop(decoded);
    }
    let stats = pool::stats();
    assert!(
        stats.hits > stats.misses,
        "pooled decode path regressed: {} hits vs {} misses",
        stats.hits,
        stats.misses
    );
    pool::clear();
}

#[test]
fn batched_params_decode_reuses_pooled_buffers() {
    // The batch frames carry the bulk of a snapshot; their tensor
    // payloads must keep the pooled-decode discipline of Deliver.
    let frame = Frame::ParamsBatch {
        entries: vec![
            ParamEntry {
                node: 0,
                params: vec![Tensor::zeros(&[32, 16]), Tensor::zeros(&[16])],
                state: Some(OptState {
                    grads: vec![Tensor::zeros(&[32, 16])],
                    m: vec![None],
                    v: vec![None],
                    pending: 0,
                    updates: 2,
                    step: 2,
                }),
            },
            ParamEntry { node: 1, params: vec![Tensor::zeros(&[64])], state: None },
        ],
    };
    let mut buf = Vec::new();
    encode_frame(&frame, &mut buf);
    pool::clear();
    for _ in 0..32 {
        let (decoded, _) = decode_frame(&buf).expect("decode");
        drop(decoded);
    }
    let stats = pool::stats();
    assert!(
        stats.hits > stats.misses,
        "batched pooled decode regressed: {} hits vs {} misses",
        stats.hits,
        stats.misses
    );
    pool::clear();
}
