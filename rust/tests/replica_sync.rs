//! §5 replica-sync barrier at the train lane's close (DESIGN.md §11).
//!
//! With `--replicas > 1`, gated interleaved eval must measure the
//! *post-sync* replicas — the plan carries the replica groups
//! ([`StreamPlan::with_sync_groups`]) and the engine averages them at
//! the gated flush, after the train lane retires and before eval
//! admits. At mak=1 the sim schedule is fully deterministic, so the
//! barrier has a bit-level oracle: a train-only stream followed by an
//! explicit [`sync_replicas`] and a drained eval epoch.

use ampnet::data::{ListRedGen, Split};
use ampnet::ir::PumpSet;
use ampnet::models::{rnn, BuiltModel, ModelCfg};
use ampnet::runtime::BackendSpec;
use ampnet::scheduler::{
    build_engine, sync_replicas, EngineKind, EpochKind, FixedMak, Lane, StreamPlan,
};

const N_TRAIN: usize = 4;
const N_VALID: usize = 2;
const TRAIN_EPOCHS: usize = 2;

fn replicated_rnn() -> BuiltModel {
    // Two replicas of the ListReduction RNN: round-robin instance
    // routing trains them on disjoint data, so their parameters diverge
    // until a sync barrier averages them.
    rnn::build(&ModelCfg::default(), ListRedGen::new(0, 300, 100, 100), 8, 2).unwrap()
}

fn train_pumps(pumper: &dyn ampnet::models::Pumper) -> Vec<PumpSet> {
    (0..N_TRAIN).map(|i| pumper.pump(Split::Train, i)).collect()
}

fn eval_pumps(pumper: &dyn ampnet::models::Pumper) -> Vec<PumpSet> {
    (0..N_VALID).map(|i| pumper.pump(Split::Valid, i)).collect()
}

#[test]
fn gated_eval_with_sync_groups_matches_drained_post_sync_oracle() {
    // Path A (oracle): train-only stream, explicit §5 averaging, then a
    // drained eval epoch over the synced parameters.
    let model_a = replicated_rnn();
    let n_nodes = model_a.graph.nodes.len();
    let groups = model_a.replica_groups.clone();
    assert!(
        groups.iter().any(|g| g.len() >= 2),
        "test needs a real replica group, got {groups:?}"
    );
    let mut eng_a =
        build_engine(EngineKind::Sim, model_a.graph, BackendSpec::native(), false).unwrap();
    let epochs_a: Vec<Vec<PumpSet>> =
        (0..TRAIN_EPOCHS).map(|_| train_pumps(model_a.pumper.as_ref())).collect();
    eng_a.run_stream(StreamPlan::train(epochs_a), &mut FixedMak::new(1)).unwrap();
    sync_replicas(eng_a.as_mut(), &groups).unwrap();
    let drained = eng_a
        .run_epoch(eval_pumps(model_a.pumper.as_ref()), 1, EpochKind::Eval)
        .unwrap();

    // Path B: identical model/seed, one gated stream whose plan carries
    // the sync groups — the engine averages at the train lane's close.
    let model_b = replicated_rnn();
    let mut eng_b =
        build_engine(EngineKind::Sim, model_b.graph, BackendSpec::native(), false).unwrap();
    let mut plan = StreamPlan::new();
    for _ in 0..TRAIN_EPOCHS {
        plan.push(Lane::Train, train_pumps(model_b.pumper.as_ref()));
    }
    plan.push(Lane::Eval, eval_pumps(model_b.pumper.as_ref()));
    let plan = plan.with_sync_groups(groups.clone());
    let stats = eng_b.run_stream(plan, &mut FixedMak::new(1)).unwrap();
    let interleaved = stats.last().unwrap();
    assert_eq!(interleaved.lane, Lane::Eval);

    // The in-stream barrier left the same post-sync parameters ...
    for node in 0..n_nodes {
        assert_eq!(
            eng_a.params_of(node).unwrap(),
            eng_b.params_of(node).unwrap(),
            "node {node}: params diverged between barrier and oracle"
        );
    }
    // ... so the gated eval numbers are bitwise the oracle's.
    assert_eq!(interleaved.instances, drained.instances);
    assert_eq!(interleaved.loss_events, drained.loss_events);
    assert_eq!(
        interleaved.loss_sum.to_bits(),
        drained.loss_sum.to_bits(),
        "gated eval loss {} != post-sync oracle {}",
        interleaved.loss_sum,
        drained.loss_sum
    );
    assert_eq!(eng_b.cached_keys().unwrap(), 0);

    // Path C (the old semantics): the same gated stream WITHOUT sync
    // groups measures the live per-replica parameters — the barrier is
    // load-bearing, not a no-op.
    let model_c = replicated_rnn();
    let mut eng_c =
        build_engine(EngineKind::Sim, model_c.graph, BackendSpec::native(), false).unwrap();
    let mut plan = StreamPlan::new();
    for _ in 0..TRAIN_EPOCHS {
        plan.push(Lane::Train, train_pumps(model_c.pumper.as_ref()));
    }
    plan.push(Lane::Eval, eval_pumps(model_c.pumper.as_ref()));
    let stats_c = eng_c.run_stream(plan, &mut FixedMak::new(1)).unwrap();
    assert_ne!(
        stats_c.last().unwrap().loss_sum.to_bits(),
        drained.loss_sum.to_bits(),
        "unsynced replicas should measure differently from the post-sync average"
    );
}
