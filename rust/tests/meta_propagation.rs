//! The metadata-propagation invariant (ISSUE 4 / DESIGN.md §10): every
//! node family must propagate `param_version` and `train` forward and
//! echo them backward — without touching either itself. The node runtime
//! owns the threading; these tests drive each family through
//! `ir::invoke_msg` and inspect the emitted messages, including the
//! Group→Ungroup and Cond→Phi round-trips that used to break the chain.

use ampnet::ir::nodes::{
    glorot, linear_params, BcastNode, ConcatNode, CondNode, EmbedNode, FlatmapNode, GroupNode,
    IsuNode, LossKind, LossNode, NptKind, NptNode, PhiNode, PptConfig, PptNode, UngroupNode,
};
use ampnet::ir::{invoke_msg, Dir, Event, Message, MsgState, Node, NodeRt, PortId};
use ampnet::optim::Optimizer;
use ampnet::runtime::{KernelFlavor, NativeBackend};
use ampnet::tensor::{ops, Tensor};
use ampnet::util::Pcg32;

/// One node under test: its runtime state plus a shared backend/sink.
struct Rig {
    be: NativeBackend,
    tx: std::sync::mpsc::Sender<Event>,
    _rx: std::sync::mpsc::Receiver<Event>,
}

impl Rig {
    fn new() -> Self {
        let (tx, rx) = std::sync::mpsc::channel();
        Rig { be: NativeBackend::new(), tx, _rx: rx }
    }

    fn drive(
        &mut self,
        node: &mut dyn Node,
        rt: &mut NodeRt,
        port: PortId,
        msg: Message,
    ) -> Vec<(PortId, Message)> {
        invoke_msg(node, rt, &mut self.be, &self.tx, 0, port, msg)
            .unwrap_or_else(|e| panic!("{}: {e:#}", node.name()))
    }
}

fn row(v: &[f32]) -> Tensor {
    Tensor::from_rows(1, v.len(), v.to_vec())
}

const V: u64 = 11;

/// Drive a 1-in/1-out glue node through fwd then bwd and assert the tag
/// and the train flag survive both directions with no leaked keys.
fn check_passthrough(node: &mut dyn Node, payload: Vec<Tensor>, bwd_payload: Vec<Tensor>) {
    let mut rig = Rig::new();
    let mut rt = NodeRt::new();
    let mut s = MsgState::for_instance(1);
    s.aux = payload[0].cols() as u32; // harmless for kinds that ignore it
    let out = rig.drive(node, &mut rt, 0, Message::fwd(s, payload).versioned(V));
    assert_eq!(out.len(), 1, "{}: one forward output", node.name());
    let (_, fwd) = &out[0];
    assert_eq!(fwd.version(), Some(V), "{}: fwd tag propagated", node.name());
    assert!(fwd.is_train(), "{}: train propagated", node.name());
    assert_eq!(fwd.hops(), 1, "{}: one runtime emission from a hop-0 pump", node.name());
    // echo: downstream returns the tag it saw
    let back = rig.drive(node, &mut rt, 0, Message::bwd(fwd.state, bwd_payload).versioned(V));
    assert_eq!(back.len(), 1, "{}: one backward output", node.name());
    assert_eq!(back[0].1.version(), Some(V), "{}: bwd echo", node.name());
    assert!(back[0].1.is_train(), "{}: bwd train", node.name());
    assert!(back[0].1.hops() >= 1, "{}: bwd hop count dropped", node.name());
    assert_eq!(rt.cached(), 0, "{}: leak-free", node.name());
}

#[test]
fn every_npt_kind_propagates_and_echoes() {
    let x = || row(&[1.0, 2.0]);
    check_passthrough(
        &mut NptNode::new("select", NptKind::Select { indices: vec![0] }),
        vec![x(), x()],
        vec![x()],
    );
    check_passthrough(
        &mut NptNode::new("sumrows", NptKind::SumRows),
        vec![Tensor::from_rows(3, 2, vec![1.0; 6])],
        vec![row(&[1.0, 1.0])],
    );
    check_passthrough(
        &mut NptNode::new("transpose", NptKind::Transpose),
        vec![x()],
        vec![Tensor::from_rows(2, 1, vec![1.0; 2])],
    );
    check_passthrough(
        &mut NptNode::new("scale", NptKind::Scale { factor: 0.5 }),
        vec![x()],
        vec![x()],
    );
    check_passthrough(
        &mut NptNode::new("mask", NptKind::MaskColsBeyondAux { neg: -1e9 }),
        vec![x()],
        vec![x()],
    );
    check_passthrough(
        &mut NptNode::new("pad", NptKind::PadCols { to: 4, fill: 0.0 }),
        vec![x()],
        vec![row(&[1.0, 1.0, 1.0, 1.0])],
    );
}

#[test]
fn isu_and_cond_phi_roundtrip_preserve_tags() {
    check_passthrough(&mut IsuNode::incr_t("isu"), vec![row(&[1.0])], vec![row(&[1.0])]);

    // Cond -> Phi chain (the loop skeleton of the RNN/GGSNN models).
    let mut rig = Rig::new();
    let mut cond = CondNode::new("cond", 2, Box::new(|s| (s.t % 2) as usize));
    let mut phi = PhiNode::new("phi");
    let (mut rt_c, mut rt_p) = (NodeRt::new(), NodeRt::new());
    let mut s = MsgState::for_instance(2);
    s.t = 1;
    let f = rig.drive(&mut cond, &mut rt_c, 0, Message::fwd(s, vec![row(&[1.0])]).versioned(V));
    let f2 = rig.drive(&mut phi, &mut rt_p, f[0].0, f[0].1.clone());
    assert_eq!(f2[0].1.version(), Some(V));
    assert!(f2[0].1.is_train());
    let b = rig.drive(&mut phi, &mut rt_p, 0, Message::bwd(s, vec![row(&[1.0])]).versioned(V));
    assert_eq!(b[0].0, 1, "phi returns to the recorded origin");
    assert_eq!(b[0].1.version(), Some(V));
    let b2 = rig.drive(&mut cond, &mut rt_c, b[0].0, b[0].1.clone());
    assert_eq!(b2[0].1.version(), Some(V), "cond echoes upstream");
    assert_eq!(rt_c.cached() + rt_p.cached(), 0);
}

#[test]
fn concat_and_bcast_merge_and_echo() {
    // Concat: max across ports forward, per-port echo backward.
    let mut rig = Rig::new();
    let mut cat = ConcatNode::new("cat", 2);
    let mut rt = NodeRt::new();
    let s = MsgState::for_instance(3);
    rig.drive(&mut cat, &mut rt, 0, Message::fwd(s, vec![row(&[1.0])]).versioned(3));
    let out = rig.drive(&mut cat, &mut rt, 1, Message::fwd(s, vec![row(&[2.0])]).versioned(V));
    assert_eq!(out[0].1.version(), Some(V), "join carries the max");
    let b = Message::bwd(s, vec![row(&[1.0, 1.0])]).versioned(V);
    let back = rig.drive(&mut cat, &mut rt, 0, b);
    assert_eq!(back[0].1.version(), Some(3), "per-port echo");
    assert_eq!(back[1].1.version(), Some(V));
    assert_eq!(rt.cached(), 0);

    // Bcast: tag replicated forward, echo restored after the sum.
    let mut bc = BcastNode::new("bc", 2);
    let mut rt = NodeRt::new();
    let f = rig.drive(&mut bc, &mut rt, 0, Message::fwd(s, vec![row(&[1.0])]).versioned(V));
    assert!(f.iter().all(|(_, m)| m.version() == Some(V)));
    rig.drive(&mut bc, &mut rt, 0, Message::bwd(s, vec![row(&[1.0])]).versioned(V));
    let done = rig.drive(&mut bc, &mut rt, 1, Message::bwd(s, vec![row(&[1.0])]).versioned(V));
    assert_eq!(done[0].1.version(), Some(V));
    assert_eq!(rt.cached(), 0);
}

#[test]
fn group_ungroup_roundtrip_preserves_tags() {
    let mut rig = Rig::new();
    let mut grp = GroupNode::new(
        "grp",
        Box::new(|s: &MsgState| {
            let mut k = *s;
            k.node = 0;
            k.key()
        }),
        Box::new(|s: &MsgState| s.aux as usize),
        Box::new(|s: &MsgState| s.node as usize),
        Box::new(|s: &MsgState, count| {
            let mut m = *s;
            m.node = 0;
            m.aux = count as u32;
            m
        }),
    );
    let mut ug = UngroupNode::new(
        "ug",
        Box::new(|s: &MsgState| {
            (0..s.aux)
                .map(|i| {
                    let mut m = *s;
                    m.node = i;
                    m.aux = 0;
                    m
                })
                .collect()
        }),
    );
    let (mut rt_g, mut rt_u) = (NodeRt::new(), NodeRt::new());
    let mut s0 = MsgState::for_instance(4);
    s0.aux = 2;
    let mut s1 = s0;
    s0.node = 0;
    s1.node = 1;
    rig.drive(&mut grp, &mut rt_g, 0, Message::fwd(s0, vec![row(&[0.0])]).versioned(2));
    let f1 = Message::fwd(s1, vec![row(&[1.0])]).versioned(V);
    let merged = rig.drive(&mut grp, &mut rt_g, 0, f1);
    assert_eq!(merged[0].1.version(), Some(V), "group merges by max");
    let members = rig.drive(&mut ug, &mut rt_u, 0, merged[0].1.clone());
    assert!(members.iter().all(|(_, m)| m.version() == Some(V)), "ungroup re-splits the tag");
    // cotangents back through Ungroup, then Group
    let mut up = Vec::new();
    for (_, m) in &members {
        let b = Message::bwd(m.state, vec![row(&[1.0])]).versioned(V);
        up = rig.drive(&mut ug, &mut rt_u, 0, b);
    }
    assert_eq!(up[0].1.version(), Some(V));
    let back = rig.drive(&mut grp, &mut rt_g, 0, up.remove(0).1);
    assert_eq!(back.len(), 2);
    assert!(back.iter().all(|(_, m)| m.version() == Some(V) && m.is_train()));
    assert_eq!(rt_g.cached() + rt_u.cached(), 0);
}

#[test]
fn flatmap_propagates_and_sums_echo() {
    let mut rig = Rig::new();
    let mut fm = FlatmapNode::new(
        "fm",
        Box::new(|s: &MsgState| {
            (0..2)
                .map(|i| {
                    let mut m = *s;
                    m.edge = i;
                    m
                })
                .collect()
        }),
    );
    let mut rt = NodeRt::new();
    let s = MsgState::for_instance(5);
    let out = rig.drive(&mut fm, &mut rt, 0, Message::fwd(s, vec![row(&[1.0])]).versioned(V));
    assert!(out.iter().all(|(_, m)| m.version() == Some(V)));
    let b0 = Message::bwd(out[0].1.state, vec![row(&[1.0])]).versioned(V);
    rig.drive(&mut fm, &mut rt, 0, b0);
    let b1 = Message::bwd(out[1].1.state, vec![row(&[1.0])]).versioned(V);
    let done = rig.drive(&mut fm, &mut rt, 0, b1);
    assert_eq!(done[0].1.version(), Some(V));
    assert_eq!(rt.cached(), 0);
}

#[test]
fn parameterized_nodes_stamp_forward_and_echo_upstream() {
    // PPT: stamps its own counter forward, echoes the upstream tag back.
    let mut rig = Rig::new();
    let mut rng = Pcg32::seeded(1);
    let mut ppt = PptNode::new(
        "lin",
        PptConfig::simple("linear", KernelFlavor::Xla, &[("i", 2), ("o", 2)], vec![1]),
        linear_params(&mut rng, 2, 2),
        Optimizer::sgd(0.1),
        1_000_000,
    );
    let mut rt = NodeRt::new();
    let s = MsgState::for_instance(6);
    let f = Message::fwd(s, vec![row(&[1.0, 2.0])]).versioned(V);
    let out = rig.drive(&mut ppt, &mut rt, 0, f);
    assert_eq!(out[0].1.version(), Some(0), "ppt stamps its own update counter");
    let b = Message::bwd(s, vec![row(&[1.0, 1.0])]).versioned(0);
    let back = rig.drive(&mut ppt, &mut rt, 0, b);
    assert_eq!(back[0].1.version(), Some(V), "ppt echoes the upstream producer");
    assert_eq!(rt.cached(), 0);

    // Embed: same contract, retire has no payload but remains tagged traffic.
    let table = glorot(&mut rng, 4, 2);
    let mut emb = EmbedNode::new("emb", table, Optimizer::sgd(0.1), 1_000_000);
    let mut rt = NodeRt::new();
    let toks = Tensor::from_rows(1, 1, vec![2.0]);
    let out = rig.drive(&mut emb, &mut rt, 0, Message::fwd(s, vec![toks]));
    assert_eq!(out[0].1.version(), Some(0), "embed stamps its table version");
    let b = Message::bwd(s, vec![row(&[1.0, 1.0])]).versioned(0);
    let back = rig.drive(&mut emb, &mut rt, 0, b);
    assert!(back[0].1.payload.is_empty());
    assert_eq!(rt.cached(), 0);

    // Loss: the backprop initiator echoes the predictor's tag.
    let mut loss = LossNode::new("loss", LossKind::Xent { classes: 2 }, vec![1]);
    let mut rt = NodeRt::new();
    rig.drive(&mut loss, &mut rt, 1, Message::fwd(s, vec![ops::one_hot(&[0], 2)]));
    let pred = Message::fwd(s, vec![row(&[2.0, 0.0])]).versioned(V);
    let fired = rig.drive(&mut loss, &mut rt, 0, pred);
    assert_eq!(fired[0].1.dir, Dir::Bwd);
    assert_eq!(fired[0].1.version(), Some(V), "loss echoes the predictor");
    assert_eq!(rt.cached(), 0);
}

#[test]
fn eval_traffic_skips_every_backward_cache() {
    let mut rig = Rig::new();
    let s = MsgState::for_instance(7);
    // one representative per family with fwd-side caches
    let checks: Vec<(Box<dyn Node>, usize, Vec<Tensor>)> = vec![
        (Box::new(NptNode::new("select", NptKind::Select { indices: vec![0] })), 0, vec![
            row(&[1.0]),
            row(&[2.0]),
        ]),
        (Box::new(PhiNode::new("phi")), 0, vec![row(&[1.0])]),
        (Box::new(BcastNode::new("bc", 2)), 0, vec![row(&[1.0])]),
        (
            Box::new(FlatmapNode::new(
                "fm",
                Box::new(|s: &MsgState| vec![*s]),
            )),
            0,
            vec![row(&[1.0])],
        ),
    ];
    for (mut node, port, payload) in checks {
        let mut rt = NodeRt::new();
        let out = rig.drive(node.as_mut(), &mut rt, port, Message::eval(s, payload));
        assert!(out.iter().all(|(_, m)| !m.is_train()), "{}: eval flag", node.name());
        assert_eq!(rt.cached(), 0, "{}: eval must cache nothing", node.name());
    }
}

/// The acceptance criterion's grep: no node implementation constructs a
/// `Message` or touches `param_version`/`train`/metadata directly — the
/// runtime owns all of it. Checked against the source text (test modules
/// excluded: they drive nodes through the public runtime API).
#[test]
fn node_sources_never_touch_messages_or_meta() {
    let sources: [(&str, &str); 6] = [
        ("agg.rs", include_str!("../src/ir/nodes/agg.rs")),
        ("control.rs", include_str!("../src/ir/nodes/control.rs")),
        ("embed.rs", include_str!("../src/ir/nodes/embed.rs")),
        ("loss.rs", include_str!("../src/ir/nodes/loss.rs")),
        ("npt.rs", include_str!("../src/ir/nodes/npt.rs")),
        ("ppt.rs", include_str!("../src/ir/nodes/ppt.rs")),
    ];
    let forbidden =
        ["Message", "MsgMeta", "param_version", ".versioned(", ".train", "Dir::", "hops"];
    for (file, src) in sources {
        let body = src.split("#[cfg(test)]").next().unwrap();
        for tok in forbidden {
            assert!(
                !body.contains(tok),
                "{file}: node implementation contains forbidden token `{tok}` — \
                 metadata and message construction belong to the node runtime (ir/rt.rs)"
            );
        }
    }
}
