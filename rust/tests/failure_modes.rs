//! Failure injection: the runtime must *diagnose* broken graphs and bad
//! configurations, not hang or silently corrupt training.

use ampnet::ir::nodes::{linear_params, LossKind, LossNode, PptConfig, PptNode};
use ampnet::ir::{MsgState, NetBuilder, Node, NodeCtx, NodeSpec, PortId, PumpSet, RoundRobin};
use ampnet::optim::Optimizer;
use ampnet::runtime::{BackendSpec, KernelFlavor};
use ampnet::scheduler::{build_engine, Engine, EngineKind, EpochKind};
use ampnet::tensor::{ops, Tensor};
use ampnet::util::Pcg32;
use anyhow::Result;

/// A node that swallows every message (simulates a lost packet / dead
/// device).
struct BlackHole;

impl Node for BlackHole {
    fn forward(
        &mut self,
        _p: PortId,
        _s: MsgState,
        _payload: Vec<Tensor>,
        _c: &mut NodeCtx,
    ) -> Result<()> {
        Ok(())
    }
    fn backward(
        &mut self,
        _p: PortId,
        _s: MsgState,
        _payload: Vec<Tensor>,
        _c: &mut NodeCtx,
    ) -> Result<()> {
        Ok(())
    }
    fn name(&self) -> &str {
        "black-hole"
    }
}

fn tiny_pump(node: usize, loss: usize, instance: u64) -> PumpSet {
    let s = MsgState::for_instance(instance);
    let mut rng = Pcg32::seeded(instance);
    let mut p = PumpSet::new(true);
    p.push(node, 0, s, vec![Tensor::new(vec![1, 4], rng.normal_vec(4, 0.5))]);
    p.push(loss, 1, s, vec![ops::one_hot(&[0], 3)]);
    p
}

fn tiny_linear(rng: &mut Pcg32, label: &str) -> PptNode {
    PptNode::new(
        label,
        PptConfig::simple("linear", KernelFlavor::Xla, &[("i", 4), ("o", 3)], vec![1]),
        linear_params(rng, 4, 3),
        Optimizer::sgd(0.1),
        1,
    )
}

#[test]
fn lost_messages_are_detected_as_deadlock() {
    let mut rng = Pcg32::seeded(1);
    let mut net = NetBuilder::new();
    let lin = net.add(NodeSpec::new("lin"), Box::new(tiny_linear(&mut rng, "lin")));
    let hole = net.add(NodeSpec::new("hole"), Box::new(BlackHole));
    let loss = net.add(
        NodeSpec::new("loss").inputs(2).outputs(0),
        Box::new(LossNode::new("loss", LossKind::Xent { classes: 3 }, vec![1])),
    );
    net.wire(lin.out(0), hole.input(0));
    // loss never receives predictions; label waits forever
    net.wire(hole.out(0), loss.input(0));
    net.controller_input(lin.input(0));
    net.controller_input(loss.input(1));
    let graph = net.build(2, &RoundRobin).unwrap().graph;
    let mut eng = build_engine(EngineKind::Sim, graph, BackendSpec::native(), false).unwrap();
    let err = eng
        .run_epoch(vec![tiny_pump(lin.id(), loss.id(), 0)], 1, EpochKind::Train)
        .unwrap_err();
    assert!(
        format!("{err:#}").contains("deadlock"),
        "expected deadlock diagnosis, got: {err:#}"
    );
}

#[test]
fn missing_artifact_error_names_the_node() {
    let mut rng = Pcg32::seeded(2);
    let mut net = NetBuilder::new();
    // dims that were never lowered by aot.py
    let lin = net.add(
        NodeSpec::new("mystery-layer"),
        Box::new(tiny_linear(&mut rng, "mystery-layer")),
    );
    let loss = net.add(
        NodeSpec::new("loss").inputs(2).outputs(0),
        Box::new(LossNode::new("loss", LossKind::Xent { classes: 3 }, vec![1])),
    );
    net.wire(lin.out(0), loss.input(0));
    net.controller_input(lin.input(0));
    net.controller_input(loss.input(1));
    let graph = net.build(1, &RoundRobin).unwrap().graph;
    // XLA backend with an EMPTY manifest: artifact lookup must fail loudly
    let spec = BackendSpec::new(
        ampnet::runtime::BackendKind::Xla,
        std::sync::Arc::new(ampnet::runtime::Manifest::empty()),
    );
    let mut eng = match build_engine(EngineKind::Sim, graph, spec, false) {
        Ok(e) => e,
        // stub xla crate: PJRT client creation itself fails — also loud
        Err(err) => {
            assert!(format!("{err:#}").contains("PJRT"), "{err:#}");
            return;
        }
    };
    let err = eng
        .run_epoch(vec![tiny_pump(lin.id(), loss.id(), 0)], 1, EpochKind::Train)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("mystery-layer"), "error should name the node: {msg}");
    assert!(msg.contains("manifest"), "error should mention the manifest: {msg}");
}

#[test]
fn checkpoint_crosses_engines() {
    use ampnet::data::{MnistLike, Split};
    use ampnet::models::{mlp, ModelCfg};
    // train in sim, checkpoint, restore into a threaded engine
    let model = mlp::build(&ModelCfg::default(), MnistLike::new(0, 300, 100, 100), 2).unwrap();
    let n_nodes = model.graph.nodes.len();
    let mut sim = build_engine(EngineKind::Sim, model.graph, BackendSpec::native(), false).unwrap();
    let pumps: Vec<_> = (0..2).map(|i| model.pumper.pump(Split::Train, i)).collect();
    sim.run_epoch(pumps, 2, EpochKind::Train).unwrap();
    let path = std::env::temp_dir().join(format!("ampnet_xengine_{}.bin", std::process::id()));
    ampnet::train::checkpoint::save(sim.as_mut(), n_nodes, &path).unwrap();

    let model2 = mlp::build(&ModelCfg::default(), MnistLike::new(0, 300, 100, 100), 2).unwrap();
    let mut thr =
        build_engine(EngineKind::Threaded, model2.graph, BackendSpec::native(), false).unwrap();
    ampnet::train::checkpoint::load(thr.as_mut(), &path).unwrap();
    for n in 0..n_nodes {
        assert_eq!(sim.params_of(n).unwrap(), thr.params_of(n).unwrap(), "node {n}");
    }
    let _ = std::fs::remove_file(path);
}

#[test]
fn eval_epoch_never_mutates_parameters() {
    use ampnet::data::{MnistLike, Split};
    use ampnet::models::{mlp, ModelCfg};
    let model = mlp::build(&ModelCfg::default(), MnistLike::new(0, 300, 200, 100), 2).unwrap();
    let n_nodes = model.graph.nodes.len();
    let mut eng = build_engine(EngineKind::Sim, model.graph, BackendSpec::native(), false).unwrap();
    let before: Vec<_> = (0..n_nodes).map(|n| eng.params_of(n).unwrap()).collect();
    let opt_before: Vec<_> = (0..n_nodes).map(|n| eng.opt_state_of(n).unwrap()).collect();
    let pumps: Vec<_> = (0..2).map(|i| model.pumper.pump(Split::Valid, i)).collect();
    let stats = eng.run_epoch(pumps, 4, EpochKind::Eval).unwrap();
    assert_eq!(stats.updates, 0, "eval must not update");
    for (n, want) in before.iter().enumerate() {
        assert_eq!(&eng.params_of(n).unwrap(), want, "node {n} changed during eval");
    }
    // optimizer state (accumulators, counters) must be untouched too
    for (n, want) in opt_before.iter().enumerate() {
        let after = eng.opt_state_of(n).unwrap();
        match (want, &after) {
            (None, None) => {}
            (Some(a), Some(b)) => {
                assert_eq!(a.grads, b.grads, "node {n}: eval touched the accumulator");
                assert_eq!(a.pending, b.pending, "node {n}: eval touched pending");
                assert_eq!(a.updates, b.updates, "node {n}: eval touched the version");
                assert_eq!(a.step, b.step, "node {n}: eval touched the step count");
            }
            _ => panic!("node {n}: optimizer state appeared/disappeared during eval"),
        }
    }
}
