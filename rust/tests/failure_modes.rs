//! Failure injection: the runtime must *diagnose* broken graphs and bad
//! configurations, not hang or silently corrupt training.

use ampnet::ir::nodes::{linear_params, LossKind, LossNode, PptConfig, PptNode};
use ampnet::ir::{GraphBuilder, Message, MsgState, Node, NodeCtx, PortId, PumpSet};
use ampnet::optim::Optimizer;
use ampnet::runtime::BackendSpec;
use ampnet::scheduler::{build_engine, Engine, EpochKind};
use ampnet::tensor::{ops, Tensor};
use ampnet::util::Pcg32;
use anyhow::Result;

/// A node that swallows every message (simulates a lost packet / dead
/// device).
struct BlackHole;

impl Node for BlackHole {
    fn forward(&mut self, _p: PortId, _m: Message, _c: &mut NodeCtx) -> Result<Vec<(PortId, Message)>> {
        Ok(Vec::new())
    }
    fn backward(&mut self, _p: PortId, _m: Message, _c: &mut NodeCtx) -> Result<Vec<(PortId, Message)>> {
        Ok(Vec::new())
    }
    fn name(&self) -> &str {
        "black-hole"
    }
}

fn tiny_pump(node: usize, loss: usize, instance: u64) -> PumpSet {
    let s = MsgState::for_instance(instance);
    let mut rng = Pcg32::seeded(instance);
    let mut p = PumpSet::new();
    p.push(node, 0, Message::fwd(s, vec![Tensor::new(vec![1, 4], rng.normal_vec(4, 0.5))]));
    p.push(loss, 1, Message::fwd(s, vec![ops::one_hot(&[0], 3)]));
    p
}

#[test]
fn lost_messages_are_detected_as_deadlock() {
    let mut rng = Pcg32::seeded(1);
    let mut g = GraphBuilder::new(2);
    let lin = g.add(
        "lin",
        0,
        Box::new(PptNode::new(
            "lin",
            PptConfig::simple("linear", "xla", &[("i", 4), ("o", 3)], vec![1]),
            linear_params(&mut rng, 4, 3),
            Optimizer::sgd(0.1),
            1,
        )),
    );
    let hole = g.add("hole", 1, Box::new(BlackHole));
    let loss = g.add("loss", 1, Box::new(LossNode::new("loss", LossKind::Xent { classes: 3 }, vec![1])));
    g.connect(lin, 0, hole, 0);
    // loss never receives predictions; label waits forever
    g.connect(hole, 0, loss, 0);
    let mut eng = build_engine("sim", g.build(), BackendSpec::native(), false).unwrap();
    let err = eng
        .run_epoch(vec![tiny_pump(lin, loss, 0)], 1, EpochKind::Train)
        .unwrap_err();
    assert!(
        format!("{err:#}").contains("deadlock"),
        "expected deadlock diagnosis, got: {err:#}"
    );
}

#[test]
fn missing_artifact_error_names_the_node() {
    let mut rng = Pcg32::seeded(2);
    let mut g = GraphBuilder::new(1);
    let lin = g.add(
        "mystery-layer",
        0,
        Box::new(PptNode::new(
            "mystery-layer",
            // dims that were never lowered by aot.py
            PptConfig::simple("linear", "xla", &[("i", 4), ("o", 3)], vec![1]),
            linear_params(&mut rng, 4, 3),
            Optimizer::sgd(0.1),
            1,
        )),
    );
    let loss = g.add("loss", 0, Box::new(LossNode::new("loss", LossKind::Xent { classes: 3 }, vec![1])));
    g.connect(lin, 0, loss, 0);
    // XLA backend with an EMPTY manifest: artifact lookup must fail loudly
    let spec = BackendSpec::new(ampnet::runtime::BackendKind::Xla, std::sync::Arc::new(ampnet::runtime::Manifest::empty()));
    let mut eng = build_engine("sim", g.build(), spec, false).unwrap();
    let err = eng
        .run_epoch(vec![tiny_pump(lin, loss, 0)], 1, EpochKind::Train)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("mystery-layer"), "error should name the node: {msg}");
    assert!(msg.contains("manifest"), "error should mention the manifest: {msg}");
}

#[test]
fn checkpoint_crosses_engines() {
    use ampnet::data::{MnistLike, Split};
    use ampnet::models::{mlp, ModelCfg};
    // train in sim, checkpoint, restore into a threaded engine
    let model = mlp::build(&ModelCfg::default(), MnistLike::new(0, 300, 100, 100), 2);
    let n_nodes = model.graph.nodes.len();
    let mut sim = build_engine("sim", model.graph, BackendSpec::native(), false).unwrap();
    let pumps: Vec<_> = (0..2).map(|i| model.pumper.pump(Split::Train, i)).collect();
    sim.run_epoch(pumps, 2, EpochKind::Train).unwrap();
    let path = std::env::temp_dir().join(format!("ampnet_xengine_{}.bin", std::process::id()));
    ampnet::train::checkpoint::save(sim.as_mut(), n_nodes, &path).unwrap();

    let model2 = mlp::build(&ModelCfg::default(), MnistLike::new(0, 300, 100, 100), 2);
    let mut thr = build_engine("threaded", model2.graph, BackendSpec::native(), false).unwrap();
    ampnet::train::checkpoint::load(thr.as_mut(), &path).unwrap();
    for n in 0..n_nodes {
        assert_eq!(sim.params_of(n).unwrap(), thr.params_of(n).unwrap(), "node {n}");
    }
    let _ = std::fs::remove_file(path);
}

#[test]
fn eval_epoch_never_mutates_parameters() {
    use ampnet::data::{MnistLike, Split};
    use ampnet::models::{mlp, ModelCfg};
    let model = mlp::build(&ModelCfg::default(), MnistLike::new(0, 300, 200, 100), 2);
    let n_nodes = model.graph.nodes.len();
    let mut eng = build_engine("sim", model.graph, BackendSpec::native(), false).unwrap();
    let before: Vec<_> = (0..n_nodes).map(|n| eng.params_of(n).unwrap()).collect();
    let pumps: Vec<_> = (0..2).map(|i| model.pumper.pump(Split::Valid, i)).collect();
    let stats = eng.run_epoch(pumps, 4, EpochKind::Eval).unwrap();
    assert_eq!(stats.updates, 0, "eval must not update");
    for (n, want) in before.iter().enumerate() {
        assert_eq!(&eng.params_of(n).unwrap(), want, "node {n} changed during eval");
    }
}
