//! End-to-end training through the full stack (XLA artifacts + sim
//! engine): each model family must demonstrably *learn* in a few epochs
//! on reduced datasets. Skipped when artifacts/ is absent.
//!
//! Tests serialize on a global mutex: they set AMP_SCALE (process-global)
//! and contend for the single CI core anyway.

use ampnet::launcher::{args_from, build_model};
use ampnet::runtime::{BackendKind, BackendSpec, Manifest};
use ampnet::train::{AmpTrainer, TrainCfg};
use once_cell_shim::Lazy;
use std::sync::{Arc, Mutex};

mod once_cell_shim {
    pub struct Lazy<T>(std::sync::OnceLock<T>, fn() -> T);
    impl<T> Lazy<T> {
        pub const fn new(f: fn() -> T) -> Self {
            Lazy(std::sync::OnceLock::new(), f)
        }
        pub fn get(&self) -> &T {
            self.0.get_or_init(self.1)
        }
    }
}

static LOCK: Lazy<Mutex<()>> = Lazy::new(|| Mutex::new(()));

fn xla_backend() -> Option<BackendSpec> {
    Manifest::load_default()
        .ok()
        .map(|m| BackendSpec::new(BackendKind::Xla, Arc::new(m)))
}

fn run(
    scale: &str,
    model: &str,
    extra: &str,
    mak: usize,
    epochs: usize,
) -> Option<ampnet::train::RunReport> {
    let _guard = LOCK.get().lock().unwrap();
    let backend = match xla_backend() {
        Some(b) => b,
        None => {
            eprintln!("artifacts not built; skipping");
            return None;
        }
    };
    std::env::set_var("AMP_SCALE", scale);
    let args = args_from(&format!("--model {model} {extra}"));
    let (m, target) = build_model(model, &args, 16).unwrap();
    let mut cfg = TrainCfg::new(backend, mak, epochs, target);
    cfg.early_stop = true;
    cfg.max_valid_instances = Some(8);
    let (r, mut engine) = AmpTrainer::run(m, &cfg).unwrap();
    assert_eq!(engine.cached_keys().unwrap(), 0);
    Some(r)
}

#[test]
fn mlp_learns_via_xla() {
    let Some(r) = run("0.004", "mlp", "", 4, 4) else { return };
    let last = r.epochs.last().unwrap();
    assert!(
        last.valid_accuracy > 0.6,
        "acc {} after {} epochs",
        last.valid_accuracy,
        r.epochs.len()
    );
}

#[test]
fn rnn_with_replicas_learns_via_xla() {
    let Some(r) = run("0.04", "rnn", "--replicas 2", 4, 3) else { return };
    let last = r.epochs.last().unwrap();
    // 10-way classification; chance = 10%
    assert!(
        last.valid_accuracy > 0.25,
        "acc {} after {} epochs",
        last.valid_accuracy,
        r.epochs.len()
    );
}

#[test]
fn tree_lstm_learns_via_xla() {
    let Some(r) = run("0.01", "tree", "", 16, 3) else { return };
    let best = r
        .epochs
        .iter()
        .map(|e| e.valid_accuracy)
        .fold(0.0f64, f64::max);
    // 5-class sentiment; must beat majority class clearly
    assert!(best > 0.4, "best acc {best} after {} epochs", r.epochs.len());
}

#[test]
fn babi_learns_via_xla() {
    let Some(r) = run("0.02", "babi", "--mak 4", 4, 4) else { return };
    let best = r
        .epochs
        .iter()
        .map(|e| e.valid_accuracy)
        .fold(0.0f64, f64::max);
    // answer is 1 of 54 nodes; the paper's target is 100%
    assert!(best >= 0.75, "best acc {best} after {} epochs", r.epochs.len());
}

#[test]
fn qm9_mae_decreases_via_xla() {
    let Some(r) = run("0.004", "qm9", "--lr 0.005 --muf 10", 8, 3) else { return };
    let first = r.epochs.first().unwrap().valid_mae;
    let last = r.epochs.last().unwrap().valid_mae;
    assert!(
        last < first,
        "validation MAE did not improve: {first} -> {last}"
    );
}
