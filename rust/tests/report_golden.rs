//! Golden-file test for the run-report JSON: a fully populated
//! `RunReport` must serialize byte-for-byte to `tests/golden/report.json`.
//! The report is a public artifact — CI uploads it, the figure scripts
//! parse it — so key renames, number-format drift (ints must print
//! without a fraction) and ordering changes (keys are emitted sorted)
//! should fail loudly, not silently reshape downstream plots.
//!
//! To regenerate after an intentional schema change:
//!   cargo test -q --test report_golden -- --nocapture
//! and paste the printed JSON into tests/golden/report.json.

use ampnet::scheduler::EpochStats;
use ampnet::train::{EpochReport, RunReport};
use ampnet::util::Json;

/// A report with every field exercised: classification counters,
/// staleness per edge, dropped grads, worker busy seconds, a reached
/// target. Values are chosen so each derived metric is an exact binary
/// fraction (no Display-rounding ambiguity).
fn golden_report() -> RunReport {
    let mut train = EpochStats {
        loss_sum: 3.0,
        loss_events: 2,
        correct: 1,
        count: 2,
        instances: 8,
        virtual_seconds: 2.0,
        wall_seconds: 2.0,
        updates: 3,
        staleness_sum: 6,
        staleness_n: 4,
        staleness_max: 3,
        grads_dropped: 1,
        messages: 40,
        occupancy_sum: 6.0,
        max_active: 4,
        worker_busy: vec![1.0, 2.0],
        ..Default::default()
    };
    let edge = train.staleness_edges.entry(2).or_default();
    edge.note(0);
    edge.note(3);
    train.staleness_edges.entry(7).or_default().note(5);
    let valid = EpochStats { instances: 4, virtual_seconds: 2.0, ..Default::default() };
    let epochs = vec![EpochReport {
        epoch: 1,
        train,
        valid,
        valid_accuracy: 0.5,
        valid_mae: 0.25,
        cum_train_seconds: 2.0,
        valid_closed_s: 1.75,
    }];
    RunReport {
        name: "golden".into(),
        epochs,
        epochs_to_target: Some(1),
        time_to_target: Some(2.5),
        train_throughput: 4.0,
        valid_throughput: 2.0,
        degraded: None,
    }
}

#[test]
fn report_json_matches_golden_file() {
    let got = golden_report().to_json().to_string();
    let want = include_str!("golden/report.json").trim();
    assert_eq!(
        got, want,
        "report JSON drifted from tests/golden/report.json — if the \
         schema change is intentional, update the golden file"
    );
}

#[test]
fn report_json_key_sets_are_stable() {
    let json = Json::parse(&golden_report().to_json().to_string()).expect("self-parse");
    let top: Vec<&str> = json.as_obj().unwrap().keys().map(String::as_str).collect();
    assert_eq!(
        top,
        ["epochs", "epochs_to_target", "name", "time_to_target", "train_inst_s", "valid_inst_s"]
    );
    let epoch = &json.get("epochs").unwrap().as_arr().unwrap()[0];
    let keys: Vec<&str> = epoch.as_obj().unwrap().keys().map(String::as_str).collect();
    assert_eq!(
        keys,
        [
            "cum_train_s",
            "epoch",
            "grads_dropped",
            "msgs_per_s",
            "occupancy",
            "staleness",
            "staleness_edges",
            "staleness_hist",
            "staleness_max",
            "train_acc",
            "train_inst_s",
            "train_loss",
            "utilization",
            "valid_acc",
            "valid_closed_s",
            "valid_inst_s",
            "valid_mae",
        ]
    );
    let edge = &epoch.get("staleness_edges").unwrap().as_arr().unwrap()[0];
    let keys: Vec<&str> = edge.as_obj().unwrap().keys().map(String::as_str).collect();
    assert_eq!(keys, ["hist", "node"]);
}

#[test]
fn unreached_target_serializes_as_null() {
    let mut report = golden_report();
    report.epochs_to_target = None;
    report.time_to_target = None;
    let s = report.to_json().to_string();
    assert!(s.contains("\"epochs_to_target\":null"), "{s}");
    assert!(s.contains("\"time_to_target\":null"), "{s}");
    // and the emitted document still parses with our own parser
    Json::parse(&s).expect("round-trip parse");
}
