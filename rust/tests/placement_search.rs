//! Measured-cost placement search, end to end (DESIGN.md §14):
//! calibration profiles round-trip through JSON and reject foreign
//! graphs, the annealing search is deterministic for a fixed seed, the
//! cost-model simulator ranks hand-built placements the same way the
//! threaded engine's measured busy times do, and — the acceptance gate —
//! tuning the GGSNN graph yields a pinned placement whose simulated
//! makespan strictly beats cost-aware LPT under the same measured
//! profile, reloadable via `--placement pinned:<path>`.

use ampnet::data::Split;
use ampnet::ir::PumpSet;
use ampnet::launcher::{args_from, build_model};
use ampnet::models::Pumper;
use ampnet::placement::{
    calibrate, lpt_assignment, search, CostProfile, PlacementFile, ProfiledCost, SearchCfg,
};
use ampnet::runtime::BackendSpec;
use ampnet::scheduler::{Engine, EpochKind, SimEngine, ThreadedEngine};
use ampnet::util::json::Json;

/// One value for the whole test binary: parallel test threads share the
/// process environment, so every test must agree on the dataset scale.
const SCALE: &str = "0.002";

/// Build `model_name`, run a seeded calibration epoch on a tracing sim
/// engine, and hand back the engine, the profile, and the pumper for
/// further workloads.
fn calibrated(
    model_name: &str,
    workers: usize,
    n_calib: usize,
) -> (SimEngine, CostProfile, Box<dyn Pumper>) {
    std::env::set_var("AMP_SCALE", SCALE);
    let (model, _target) = build_model(model_name, &args_from("--seed 42"), workers).unwrap();
    let pumps: Vec<PumpSet> =
        (0..n_calib).map(|i| model.pumper.pump(Split::Train, i)).collect();
    let mut eng = SimEngine::new(model.graph, BackendSpec::native(), true).unwrap();
    let profile = calibrate(&mut eng, pumps, 4, model_name).unwrap();
    (eng, profile, model.pumper)
}

#[test]
fn profile_roundtrips_and_rejects_foreign_graph() {
    let (eng, profile, _pumper) = calibrated("qm9", 8, 16);
    profile.validate(eng.graph()).unwrap();
    assert!(
        profile.measured_costs().iter().any(|&c| c > 0),
        "calibration measured no compute at all"
    );
    // JSON round-trip is lossless (f64 Display is shortest-roundtrip).
    let back =
        CostProfile::from_json(&Json::parse(&profile.to_json().to_string()).unwrap()).unwrap();
    assert_eq!(back, profile);
    assert_eq!(back.measured_costs(), profile.measured_costs());
    // A different topology must be rejected loudly, not mispriced.
    std::env::set_var("AMP_SCALE", SCALE);
    let (mlp, _t) = build_model("mlp", &args_from("--seed 42"), 8).unwrap();
    let err = profile.validate(&mlp.graph).unwrap_err();
    assert!(format!("{err:#}").contains("stale cost profile"), "{err:#}");
}

#[test]
fn search_is_deterministic_for_a_fixed_seed() {
    let (mut eng, profile, pumper) = calibrated("mlp", 4, 12);
    let pumps: Vec<PumpSet> = (0..8).map(|i| pumper.pump(Split::Train, i)).collect();
    let cfg = SearchCfg { seed: 11, max_iters: 60, budget_s: None, relay: false };
    // Back-to-back searches on the same engine: training mutates the
    // parameters between runs, but under a cost model the makespan is a
    // pure function of the assignment, so both runs must agree bit-wise.
    let a = search(&mut eng, &profile, &pumps, 4, &cfg).unwrap();
    let b = search(&mut eng, &profile, &pumps, 4, &cfg).unwrap();
    assert_eq!(a.assignment, b.assignment);
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
    assert_eq!(a.lpt_makespan.to_bits(), b.lpt_makespan.to_bits());
    assert_eq!((a.iters, a.accepted), (b.iters, b.accepted));
    assert!(a.makespan <= a.lpt_makespan, "search never returns worse than its LPT seed");
}

#[test]
fn sim_ranking_matches_threaded_measured_busy() {
    const WORKERS: usize = 4;
    let (mut eng, profile, pumper) = calibrated("mlp", WORKERS, 12);
    let n_nodes = eng.graph().nodes.len();
    let costs = profile.measured_costs();
    // Three hand-built placements with cleanly separated load balance:
    // everything serialized onto worker 0; measured-cost LPT with the
    // second-heaviest node deliberately colocated onto the heaviest's
    // worker; and plain measured-cost LPT.
    let serial = vec![0usize; n_nodes];
    let mut order: Vec<usize> = (0..n_nodes).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(costs[i]));
    let balanced = lpt_assignment(&costs, WORKERS);
    let mut colocated = balanced.clone();
    colocated[order[1]] = colocated[order[0]];
    let placements = [serial, colocated, balanced];

    // Sim-predicted makespans under the calibrated cost model.
    eng.set_cost_model(Some(Box::new(ProfiledCost::new(&profile, eng.graph()))));
    let pumps: Vec<PumpSet> = (0..16).map(|i| pumper.pump(Split::Train, i)).collect();
    let mut predicted = Vec::new();
    for asg in &placements {
        eng.graph_mut().set_workers(asg);
        let stats = eng.run_epoch(pumps.clone(), 8, EpochKind::Train).unwrap();
        predicted.push(stats.virtual_seconds);
    }
    eng.set_cost_model(None);

    // Measured side: the threaded engine's per-worker busy time is pure
    // compute accumulation, so its max is robust on a single-core host
    // where epoch wall time is not.
    let mut measured = Vec::new();
    for asg in &placements {
        std::env::set_var("AMP_SCALE", SCALE);
        let (model, _t) = build_model("mlp", &args_from("--seed 42"), WORKERS).unwrap();
        let pumps: Vec<PumpSet> = (0..16).map(|i| model.pumper.pump(Split::Train, i)).collect();
        let mut graph = model.graph;
        graph.set_workers(asg);
        let mut teng = ThreadedEngine::new(graph, BackendSpec::native(), false).unwrap();
        let stats = teng.run_epoch(pumps, 8, EpochKind::Train).unwrap();
        measured.push(stats.worker_busy.iter().cloned().fold(0.0f64, f64::max));
    }

    let rank = |xs: &[f64]| {
        let mut idx: Vec<usize> = (0..xs.len()).collect();
        idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
        idx
    };
    assert_eq!(
        rank(&predicted),
        rank(&measured),
        "sim-predicted makespans {predicted:?} rank placements differently \
         than the threaded engine's measured busy maxima {measured:?}"
    );
}

/// The acceptance gate: tuning the GGSNN graph under a measured profile
/// finds a placement that strictly beats cost-aware LPT's simulated
/// makespan, the engine's graph carries the winner on return, and the
/// emitted pinned file reloads through the launcher (and is rejected for
/// a different topology).
#[test]
fn tuned_ggsnn_placement_beats_lpt_and_reloads() {
    let (mut eng, profile, pumper) = calibrated("qm9", 16, 24);
    let pumps: Vec<PumpSet> = (0..8).map(|i| pumper.pump(Split::Train, i)).collect();
    let cfg = SearchCfg { seed: 7, max_iters: 600, budget_s: None, relay: false };
    let res = search(&mut eng, &profile, &pumps, 4, &cfg).unwrap();
    assert!(
        res.makespan < res.lpt_makespan,
        "search failed to beat LPT: tuned {} vs lpt {} after {} iters ({} accepted)",
        res.makespan,
        res.lpt_makespan,
        res.iters,
        res.accepted
    );
    let workers: Vec<usize> = eng.graph().nodes.iter().map(|s| s.worker).collect();
    assert_eq!(workers, res.assignment, "engine graph carries the winner on return");

    let pf = PlacementFile {
        model: "qm9".into(),
        fingerprint: profile.fingerprint,
        n_workers: 16,
        assignment: res.assignment.clone(),
        predicted_makespan: res.makespan,
        lpt_makespan: res.lpt_makespan,
    };
    let path = std::env::temp_dir()
        .join(format!("ampnet_tuned_qm9_{}.json", std::process::id()))
        .to_string_lossy()
        .into_owned();
    pf.save(&path).unwrap();
    // Loading through the launcher applies the pinned assignment and
    // validates the topology fingerprint against the rebuilt graph.
    let (reloaded, _t) =
        build_model("qm9", &args_from(&format!("--seed 42 --placement pinned:{path}")), 16)
            .unwrap();
    let got: Vec<usize> = reloaded.graph.nodes.iter().map(|s| s.worker).collect();
    assert_eq!(got, res.assignment);
    // A different worker count is a different topology: rejected.
    assert!(
        build_model("qm9", &args_from(&format!("--seed 42 --placement pinned:{path}")), 8)
            .is_err(),
        "pinned placement for 16 workers must not load into an 8-worker build"
    );
    let _ = std::fs::remove_file(&path);
}
