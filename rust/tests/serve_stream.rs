//! Online inference serving, end to end (DESIGN.md §15).
//!
//! The serving lane rides the live training stream on every engine; the
//! tests here pin its safety and observability contract:
//!
//! * the inference lane NEVER mutates parameters or optimizer state;
//! * responses served from the same CoW snapshot epoch are bit-equal,
//!   even while training mutates the live parameters concurrently;
//! * deadline shedding in the sim engine is deterministic — the shed set
//!   is a pure function of the script and the cost model;
//! * threaded and sim latency telemetry both pass basic sanity;
//! * the ISSUE acceptance: serving at the default quota neither degrades
//!   final train loss beyond 5% relative nor breaks instance accounting
//!   (every request completed or typed-shed, exactly once).

use std::collections::HashMap;
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use ampnet::data::{MnistLike, Split};
use ampnet::launcher::{args_from, build_model};
use ampnet::models::{mlp, BuiltModel, ModelCfg};
use ampnet::runtime::BackendSpec;
use ampnet::scheduler::{build_engine, AdmissionKind, EngineKind, Lane, StreamPlan};
use ampnet::serve::{ServeOutcome, ServeShared, ShedReason};
use ampnet::train::{AmpTrainer, ServeCfg, TargetMetric, TrainCfg};
use ampnet::transport::{RemoteSpec, TransportKind};

fn build(seed: u64) -> BuiltModel {
    let mut mcfg = ModelCfg::default();
    mcfg.lr = 0.1;
    mcfg.muf = 100;
    // 1000 validation samples = 10 batched eval instances, so inline
    // serving scripts carry enough requests for percentile telemetry.
    mlp::build(&mcfg, MnistLike::new(seed, 500, 1000, 100), 4).unwrap()
}

/// Run one sim stream: a train epoch plus a scripted serve lane, and
/// return the responses (id -> outcome/epoch/latency).
fn run_scripted(
    script: &[(f64, usize, u32)],
    quota: f64,
) -> (ServeShared, Vec<ampnet::serve::InferResponse>) {
    let model = build(7);
    let mut eng =
        build_engine(EngineKind::Sim, model.graph, BackendSpec::native(), false).unwrap();
    let pumps: Vec<_> =
        (0..model.pumper.n(Split::Train)).map(|i| model.pumper.pump(Split::Train, i)).collect();
    let shared = ServeShared::scripted(script);
    let pumper = model.pumper;
    let nv = pumper.n(Split::Valid);
    let plan = StreamPlan::train(vec![pumps]).with_serve(
        shared.clone(),
        quota,
        Box::new(move |req| {
            pumper
                .pump(Split::Valid, req.index % nv)
                .into_lane(Lane::Infer, req.deadline_us)
                .with_instance(req.id)
        }),
    );
    let mut policy = AdmissionKind::Fixed.policy(4);
    eng.run_stream(plan, policy.as_mut()).unwrap();
    assert_eq!(eng.cached_keys().unwrap(), 0, "serving leaked cached keys");
    let responses = shared.take_responses();
    (shared, responses)
}

#[test]
fn inference_lane_never_mutates_params_or_optimizer_state() {
    let model = build(3);
    let n_nodes = model.graph.nodes.len();
    let mut eng =
        build_engine(EngineKind::Sim, model.graph, BackendSpec::native(), false).unwrap();
    let params_before: Vec<_> = (0..n_nodes).map(|n| eng.params_of(n).unwrap()).collect();
    let opt_before: Vec<_> = (0..n_nodes).map(|n| eng.opt_state_of(n).unwrap()).collect();

    // A pure-serve stream: no train work at all, only scripted requests.
    let script: Vec<(f64, usize, u32)> = (0..6).map(|k| (k as f64 * 0.01, k, 0)).collect();
    let shared = ServeShared::scripted(&script);
    let pumper = model.pumper;
    let nv = pumper.n(Split::Valid);
    let plan = StreamPlan::new().with_serve(
        shared.clone(),
        0.5,
        Box::new(move |req| {
            pumper
                .pump(Split::Valid, req.index % nv)
                .into_lane(Lane::Infer, req.deadline_us)
                .with_instance(req.id)
        }),
    );
    let mut policy = AdmissionKind::Fixed.policy(4);
    eng.run_stream(plan, policy.as_mut()).unwrap();

    let responses = shared.take_responses();
    assert_eq!(responses.len(), 6);
    assert!(responses.iter().all(|r| r.is_ok()), "{responses:?}");

    for (n, want) in params_before.iter().enumerate() {
        assert_eq!(&eng.params_of(n).unwrap(), want, "node {n}: serving changed parameters");
    }
    for (n, want) in opt_before.iter().enumerate() {
        let after = eng.opt_state_of(n).unwrap();
        match (want, &after) {
            (None, None) => {}
            (Some(a), Some(b)) => {
                assert_eq!(a.grads, b.grads, "node {n}: serving touched the accumulator");
                assert_eq!(a.pending, b.pending, "node {n}: serving touched pending");
                assert_eq!(a.updates, b.updates, "node {n}: serving touched the version");
                assert_eq!(a.step, b.step, "node {n}: serving touched the step count");
            }
            _ => panic!("node {n}: optimizer state appeared/disappeared during serving"),
        }
    }
}

#[test]
fn same_snapshot_epoch_responses_are_bit_equal_under_concurrent_training() {
    // Twelve requests for the SAME validation sample, spread across a
    // training epoch that is concurrently mutating the live parameters.
    let script: Vec<(f64, usize, u32)> = (0..12).map(|k| (k as f64 * 0.02, 3, 0)).collect();
    let (_shared, responses) = run_scripted(&script, 0.5);
    assert_eq!(responses.len(), 12);

    let mut by_epoch: HashMap<u64, Vec<ampnet::tensor::Tensor>> = HashMap::new();
    let mut served = 0usize;
    for r in &responses {
        let ServeOutcome::Ok(out) = &r.outcome else {
            panic!("no-deadline request shed: {r:?}")
        };
        served += 1;
        assert!(!out.is_empty(), "inference produced no output");
        match by_epoch.get(&r.snapshot_epoch) {
            None => {
                by_epoch.insert(r.snapshot_epoch, out.clone());
            }
            Some(want) => assert_eq!(
                want, out,
                "responses from snapshot epoch {} diverged — serving must read \
                 the frozen snapshot, not the live parameters",
                r.snapshot_epoch
            ),
        }
    }
    assert_eq!(served, 12);
}

#[test]
fn deadline_shedding_is_deterministic_in_sim() {
    // Mix of generous (0 = none) and impossible (1us) deadlines; run the
    // identical script twice and require the identical outcome per id.
    let script: Vec<(f64, usize, u32)> = (0..16)
        .map(|k| (k as f64 * 0.015, k % 4, if k % 3 == 0 { 1 } else { 0 }))
        .collect();
    let outcomes = |responses: &[ampnet::serve::InferResponse]| -> Vec<(u64, Option<ShedReason>)> {
        let mut v: Vec<_> = responses
            .iter()
            .map(|r| {
                (
                    r.id,
                    match r.outcome {
                        ServeOutcome::Ok(_) => None,
                        ServeOutcome::Shed(reason) => Some(reason),
                    },
                )
            })
            .collect();
        v.sort_by_key(|(id, _)| *id);
        v
    };
    let (_s1, r1) = run_scripted(&script, 0.25);
    let (_s2, r2) = run_scripted(&script, 0.25);
    assert_eq!(r1.len(), 16);
    assert_eq!(outcomes(&r1), outcomes(&r2), "shed decisions must be deterministic");
    // at least the no-deadline requests completed
    assert!(r1.iter().filter(|r| r.is_ok()).count() >= 10, "{:?}", outcomes(&r1));
}

fn serve_run(engine: EngineKind) -> ampnet::serve::ServeReport {
    let model = build(11);
    let mut cfg = TrainCfg::new(BackendSpec::native(), 4, 2, TargetMetric::Accuracy(0.99));
    cfg.engine = engine;
    cfg.early_stop = false;
    cfg.serve = Some(ServeCfg::Inline { rate: 200.0, deadline_ms: 0 });
    let (report, mut eng) = AmpTrainer::run(model, &cfg).unwrap();
    assert_eq!(eng.cached_keys().unwrap(), 0);
    report.serve.expect("serve section")
}

#[test]
fn threaded_and_sim_latency_telemetry_pass_sanity() {
    for engine in [EngineKind::Sim, EngineKind::Threaded] {
        let sv = serve_run(engine);
        assert!(sv.submitted > 0, "{engine:?}: {sv:?}");
        assert_eq!(sv.completed + sv.total_shed(), sv.submitted, "{engine:?}: {sv:?}");
        assert_eq!(sv.completed, sv.submitted, "no deadlines => nothing shed: {engine:?}");
        assert!(sv.p50_latency > 0.0, "{engine:?}: {sv:?}");
        assert!(sv.p99_latency >= sv.p50_latency, "{engine:?}: {sv:?}");
        // loose wall-clock sanity on the live engine: a tiny MLP answer
        // cannot reasonably take a minute
        assert!(sv.p99_latency < 60.0, "{engine:?}: {sv:?}");
        assert!(sv.snapshot_epochs >= 1, "{engine:?}: {sv:?}");
    }
}

/// ISSUE acceptance: inference at the default quota does not degrade
/// final train loss by more than 5% relative, and instance accounting
/// stays exact.
#[test]
fn serving_at_default_quota_preserves_training() {
    let run = |serve: Option<ServeCfg>| {
        let model = build(5);
        let mut cfg = TrainCfg::new(BackendSpec::native(), 4, 3, TargetMetric::Accuracy(0.99));
        cfg.early_stop = false;
        cfg.serve = serve;
        let (report, mut eng) = AmpTrainer::run(model, &cfg).unwrap();
        assert_eq!(eng.cached_keys().unwrap(), 0);
        report
    };
    let clean = run(None);
    let served = run(Some(ServeCfg::Inline { rate: 100.0, deadline_ms: 0 }));

    assert!(clean.serve.is_none());
    let sv = served.serve.as_ref().expect("serve section");
    assert_eq!(sv.completed + sv.total_shed(), sv.submitted, "accounting exact: {sv:?}");
    assert!(sv.completed > 0, "{sv:?}");

    // same epoch walk, same per-epoch train instance counts
    assert_eq!(clean.epochs.len(), served.epochs.len());
    for (a, b) in clean.epochs.iter().zip(&served.epochs) {
        assert_eq!(a.train.instances, b.train.instances, "epoch {}", a.epoch);
        assert_eq!(a.train.loss_events, b.train.loss_events, "epoch {}", a.epoch);
    }
    let l0 = clean.epochs.last().unwrap().train.mean_loss();
    let l1 = served.epochs.last().unwrap().train.mean_loss();
    assert!(
        (l1 - l0).abs() <= 0.05 * l0.abs().max(1e-12),
        "serving degraded final train loss: {l0} -> {l1}"
    );
}

// ---- worker-loss recovery: in-flight inference is shed, not requeued ----

const SCALE: &str = "0.002";

fn sock_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("ampnet_{tag}_{}.sock", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn spawn_worker(sock: &str) -> Child {
    Command::new(env!("CARGO_BIN_EXE_ampnet"))
        .args(["worker", "--listen", sock, "--transport", "uds"])
        .env("AMP_SCALE", SCALE)
        .stdout(Stdio::null())
        .spawn()
        .expect("spawn ampnet worker")
}

fn wait_child(mut c: Child) {
    for _ in 0..100 {
        match c.try_wait().expect("try_wait") {
            Some(_) => return,
            None => std::thread::sleep(Duration::from_millis(100)),
        }
    }
    let _ = c.kill();
    let _ = c.wait();
    panic!("worker did not exit after shutdown");
}

/// Satellite 6: a scripted mid-stream worker kill with serving attached.
/// Recovery re-admits lost *training* work but sheds in-flight inference
/// with the typed `WorkerLoss` reason — the `Degraded.shed_inference`
/// count and the serve report's `shed_worker_loss` are the same number,
/// and accounting stays exact (nothing requeued, nothing double-counted).
#[test]
fn scripted_kill_sheds_inflight_inference_with_typed_count() {
    std::env::set_var("AMP_SCALE", SCALE);
    let s0 = sock_path("serve_kill_w0");
    let s1 = sock_path("serve_kill_w1");
    let w0 = spawn_worker(&s0);
    let w1 = spawn_worker(&s1);

    let (model, target) = build_model("mlp", &args_from("--seed 42"), 8).unwrap();
    let mut cfg = TrainCfg::new(BackendSpec::native(), 1, 2, target);
    cfg.engine = EngineKind::Threaded;
    cfg.early_stop = false;
    cfg.max_train_instances = Some(40);
    cfg.max_valid_instances = Some(50);
    cfg.transport = Some(TransportKind::Uds);
    cfg.workers_remote = vec![s0, s1];
    cfg.remote = Some(RemoteSpec { model: "mlp".into(), args: "--seed 42".into() });
    cfg.fault_plan = Some("kill:worker=1@step=3".parse().unwrap());
    // burst the whole script immediately so requests are in flight (or
    // pending) when the kill lands
    cfg.serve = Some(ServeCfg::Inline { rate: 5000.0, deadline_ms: 0 });
    let (report, engine) =
        AmpTrainer::run(model, &cfg).expect("faulted serving run recovers instead of aborting");
    drop(engine); // Shutdown + close before waiting on the workers

    let d = report.degraded.expect("kill run reports a degraded section");
    let sv = report.serve.expect("serve section");
    assert_eq!(
        d.shed_inference, sv.shed_worker_loss,
        "typed shed counts must agree: {d:?} vs {sv:?}"
    );
    assert_eq!(sv.completed + sv.total_shed(), sv.submitted, "accounting exact: {sv:?}");
    assert_eq!(sv.shed_deadline, 0, "no deadlines in this script: {sv:?}");
    // worker-loss sheds are final — a shed request never re-enters the
    // queue, so served + shed covers the script exactly once
    assert_eq!(
        sv.completed + sv.shed_worker_loss + sv.shed_shutdown,
        sv.submitted,
        "{sv:?}"
    );
    wait_child(w0);
    wait_child(w1);
}
