//! Cross-check the two compute paths over every AOT artifact:
//!
//!   XLA artifact (jax math, incl. Pallas kernels)  vs  native Rust ops.
//!
//! Combined with python/tests (Pallas vs jnp oracle) this closes the loop:
//! jnp oracle == Pallas kernel == HLO artifact == native Rust.
//!
//! Skipped gracefully when `artifacts/` has not been built. Large dense-
//! baseline matmuls are skipped unless AMP_PARITY_ALL=1 (they're slow on
//! the 1-core CI container but add no new code paths).

use std::sync::Arc;

use ampnet::runtime::{Backend, Manifest, NativeBackend, XlaBackend};
use ampnet::tensor::{ops, Tensor};
use ampnet::util::Pcg32;

fn manifest() -> Option<Manifest> {
    let dir = std::env::var("AMP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    Manifest::load(dir).ok()
}

fn rand_inputs(shapes: &[Vec<usize>], op: &str, rng: &mut Pcg32) -> Vec<Tensor> {
    shapes
        .iter()
        .enumerate()
        .map(|(idx, s)| {
            let n: usize = s.iter().product();
            // Losses want one-hot / mask inputs at specific positions.
            if op.starts_with("xent") && idx == 1 {
                let rows = s[0];
                let classes = s[1];
                let labels: Vec<usize> =
                    (0..rows).map(|_| rng.below_usize(classes)).collect();
                ops::one_hot(&labels, classes)
            } else if op.starts_with("mse") && idx == 2 {
                Tensor::new(s.clone(), (0..n).map(|_| 1.0).collect())
            } else {
                Tensor::new(s.clone(), rng.normal_vec(n, 0.5))
            }
        })
        .collect()
}

#[test]
fn xla_and_native_agree_on_every_artifact() {
    let Some(m) = manifest() else {
        eprintln!("parity: artifacts/ not built; skipping");
        return;
    };
    let m = Arc::new(m);
    let mut xla = XlaBackend::new(m.clone()).expect("pjrt client");
    let mut native = NativeBackend::new();
    let all = std::env::var("AMP_PARITY_ALL").is_ok();
    let mut rng = Pcg32::seeded(0xA117);
    let mut checked = 0usize;
    for name in m.names().map(String::from).collect::<Vec<_>>() {
        let spec = m.get(&name).unwrap().clone();
        let work: usize = spec.inputs.iter().map(|s| s.iter().product::<usize>()).sum();
        if !all && work > 600_000 {
            continue; // large dense-baseline matmuls: same code path, slow
        }
        let ins = rand_inputs(&spec.inputs, &spec.op, &mut rng);
        let got_x = xla
            .execute(&name, &ins)
            .unwrap_or_else(|e| panic!("xla exec {name}: {e:#}"));
        let got_n = native
            .execute(&name, &ins)
            .unwrap_or_else(|e| panic!("native exec {name}: {e:#}"));
        assert_eq!(got_x.len(), got_n.len(), "{name}: output arity");
        for (i, (a, b)) in got_x.iter().zip(&got_n).enumerate() {
            assert_eq!(a.shape(), b.shape(), "{name} out {i} shape");
            let d = ops::rel_diff(a, b);
            assert!(
                d < 2e-3,
                "{name} output {i}: xla vs native rel diff {d}"
            );
        }
        checked += 1;
    }
    assert!(checked > 60, "only {checked} artifacts checked — manifest too small?");
    eprintln!("parity: {checked} artifacts agree (xla vs native)");
}

#[test]
fn pallas_and_xla_flavors_agree_via_pjrt() {
    // The flavor pair executes *different HLO* (pallas interpret expansion
    // vs plain jnp lowering); both must produce the same numbers through
    // the actual PJRT path the runtime uses.
    let Some(m) = manifest() else {
        eprintln!("parity: artifacts/ not built; skipping");
        return;
    };
    let m = Arc::new(m);
    let mut xla = XlaBackend::new(m.clone()).expect("pjrt client");
    let mut rng = Pcg32::seeded(0xB225);
    let mut checked = 0usize;
    for name in m.names().map(String::from).collect::<Vec<_>>() {
        if !name.ends_with("__pallas") {
            continue;
        }
        let twin = name.replace("__pallas", "__xla");
        if !m.contains(&twin) {
            continue;
        }
        let spec = m.get(&name).unwrap().clone();
        let work: usize = spec.inputs.iter().map(|s| s.iter().product::<usize>()).sum();
        if work > 600_000 {
            continue;
        }
        let ins = rand_inputs(&spec.inputs, &spec.op, &mut rng);
        let a = xla.execute(&name, &ins).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        let b = xla.execute(&twin, &ins).unwrap_or_else(|e| panic!("{twin}: {e:#}"));
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            let d = ops::rel_diff(x, y);
            assert!(d < 1e-3, "{name} vs {twin} out {i}: rel diff {d}");
        }
        checked += 1;
    }
    assert!(checked > 10, "only {checked} pallas/xla pairs checked");
    eprintln!("parity: {checked} pallas/xla flavor pairs agree");
}
