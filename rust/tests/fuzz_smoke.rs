//! Bounded fuzz smoke (ISSUE 7 satellite): in-tree, dependency-free
//! mirrors of the two `rust/fuzz` cargo-fuzz targets, so CI exercises
//! the same no-panic contracts on every push without libfuzzer.
//!
//! * `transport::wire::decode_frame` over random bytes and over
//!   bit-flipped/truncated/extended valid frames — must return
//!   `Ok`/`Err`, never panic or over-allocate;
//! * `NetBuilder::build` over randomized graph recipes (arities, pins,
//!   dims, edges, pump ports, placement) — malformed wiring must come
//!   back as a diagnostic `Err`, never a panic.
//!
//! Iteration count: `AMP_FUZZ_ITERS` (default 1000). The real coverage-
//! guided targets live in `rust/fuzz/` and run on a networked machine
//! via `cargo +nightly fuzz run wire_decode|net_builder`.

use ampnet::ir::nodes::IsuNode;
use ampnet::ir::{NetBuilder, NodeSpec, PlacementKind};
use ampnet::tensor::Tensor;
use ampnet::transport::wire::{decode_frame, encode_frame};
use ampnet::transport::{Frame, Hello};
use ampnet::util::Pcg32;

fn iters() -> u64 {
    std::env::var("AMP_FUZZ_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(1_000)
}

/// One valid frame of every shape the smoke can build without a live
/// engine (Deliver/Event need runtime message plumbing; the cargo-fuzz
/// target reaches those kinds through its byte-level corpus instead).
fn corpus() -> Vec<Frame> {
    vec![
        Frame::Hello(Hello {
            model: "mlp".into(),
            args: "--seed 42".into(),
            workers: 8,
            n_shards: 2,
            shard: 1,
            scale: 0.01,
            backend: "native".into(),
            trace: false,
            heartbeat_ms: 250,
            fingerprint: 0xfeed_beef,
            peer_listen: "uds:/tmp/w1.sock.peer".into(),
            peers: vec!["uds:/tmp/w0.sock.peer".into(), "uds:/tmp/w1.sock.peer".into()],
            fault_plan: "kill:link=0-1@step=2;seed=9".into(),
        }),
        Frame::HelloAck { fingerprint: 0xfeed_beef, nodes: 9 },
        Frame::Retire { instance: 17, hops: 3 },
        Frame::EpochStart,
        Frame::EpochMark { epoch: 4 },
        Frame::FlushParams,
        Frame::FlushParamsAck,
        Frame::Flush,
        Frame::GetParams { node: 2 },
        Frame::Params {
            node: 2,
            params: vec![Tensor::from_vec(vec![1.0, -2.5, 3.25]), Tensor::zeros(&[2, 3])],
        },
        Frame::SetParams { node: 1, params: vec![Tensor::scalar(0.5)] },
        Frame::SetParamsAck { node: 1 },
        Frame::GetOptState { node: 0 },
        Frame::OptStateReply { node: 0, state: None },
        Frame::SetOptStateAck { node: 0, err: Some("shape mismatch".into()) },
        Frame::CachedKeys,
        Frame::CachedKeysReply { n: 11 },
        Frame::PeerHello { from: 3 },
        Frame::PeerDrain { token: 12 },
        Frame::PeerDrainAck { token: 12, sent: vec![0, 4, 1], recv: vec![2, 0, 0] },
        Frame::Heartbeat { backlog: 7 },
        Frame::Shutdown,
        Frame::Abort { msg: "fault injection".into() },
    ]
}

#[test]
fn wire_decoder_survives_random_bytes() {
    let mut rng = Pcg32::seeded(0xF022);
    for _ in 0..iters() {
        let len = rng.next_u32() as usize % 512;
        let buf: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
        if let Ok((frame, used)) = decode_frame(&buf) {
            assert!(used <= buf.len());
            let _ = format!("{frame:?}");
        }
    }
}

#[test]
fn wire_decoder_survives_mutated_valid_frames() {
    let corpus = corpus();
    let mut rng = Pcg32::seeded(0xF023);
    let mut buf = Vec::new();
    for frame in &corpus {
        encode_frame(frame, &mut buf);
        let (_, used) = decode_frame(&buf).expect("corpus frame round-trips");
        assert_eq!(used, buf.len());
    }
    for _ in 0..iters() {
        let frame = &corpus[rng.next_u32() as usize % corpus.len()];
        encode_frame(frame, &mut buf);
        let mut bad = buf.clone();
        match rng.next_u32() % 3 {
            0 => {
                // Flip one byte anywhere (header, length field, or body).
                let i = rng.next_u32() as usize % bad.len();
                bad[i] ^= (rng.next_u32() % 255 + 1) as u8;
            }
            1 => bad.truncate(rng.next_u32() as usize % bad.len()),
            _ => bad.extend((0..1 + rng.next_u32() % 16).map(|_| rng.next_u32() as u8)),
        }
        if let Ok((frame, used)) = decode_frame(&bad) {
            assert!(used <= bad.len());
            let _ = format!("{frame:?}");
        }
    }
}

/// Mirror of `fuzz_targets/net_builder.rs`: interpret a byte string as a
/// graph recipe and build it. Kept in lockstep with the fuzz target so a
/// crash found by either reproduces in the other.
fn build_recipe(data: &[u8]) -> anyhow::Result<ampnet::ir::Net> {
    let mut pos = 0usize;
    let mut next = move || {
        let b = data.get(pos).copied().unwrap_or(0);
        pos += 1;
        b
    };
    let n = 1 + (next() as usize % 8);
    let mut builder = NetBuilder::new();
    let mut handles = Vec::with_capacity(n);
    for i in 0..n {
        let label = format!("n{i}");
        let mut spec = NodeSpec::new(&label)
            .inputs(next() as usize % 4)
            .outputs(next() as usize % 4)
            .cost(next() as u64);
        let pin = next();
        if pin & 1 == 1 {
            spec = spec.pin((pin >> 1) as usize % 6);
        }
        let d = next();
        if d & 1 == 1 {
            spec = spec.out_dim((d as usize >> 1) % 3, 1 + d as usize);
        }
        let d = next();
        if d & 1 == 1 {
            spec = spec.in_dim((d as usize >> 1) % 3, 1 + d as usize);
        }
        handles.push(builder.add(spec, Box::new(IsuNode::incr_t(&label))));
    }
    for _ in 0..next() as usize % 16 {
        let from = handles[next() as usize % n];
        let to = handles[next() as usize % n];
        builder.wire(from.out(next() as usize % 5), to.input(next() as usize % 5));
    }
    for _ in 0..next() as usize % 8 {
        let node = handles[next() as usize % n];
        builder.controller_input(node.input(next() as usize % 5));
    }
    if next() & 1 == 1 {
        builder.replica_group(&handles);
    }
    let workers = 1 + next() as usize % 4;
    let kind = PlacementKind::ALL[next() as usize % PlacementKind::ALL.len()];
    builder.build(workers, kind.strategy().as_ref())
}

#[test]
fn net_builder_survives_random_recipes() {
    let mut rng = Pcg32::seeded(0xF024);
    let mut rejected = 0u64;
    for _ in 0..iters() {
        let len = rng.next_u32() as usize % 128;
        let recipe: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
        // Valid or not, build() must diagnose — never panic.
        if let Err(e) = build_recipe(&recipe) {
            assert!(!format!("{e:#}").is_empty());
            rejected += 1;
        }
    }
    // Sanity: random wiring should actually exercise the error paths —
    // an all-Ok run means the recipe interpreter stopped generating
    // interesting graphs.
    assert!(rejected > 0, "generator produced no invalid graphs in {} iters", iters());
}
