"""L2 correctness: explicit backward ops vs jax.grad of the forward ops,
and pallas flavor vs xla flavor of every op."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def _rand(rng, *shape, scale=0.5):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


def _close(a, b, rtol=2e-3, atol=1e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol)


def _inputs_for(op, dims, seed=0):
    rng = np.random.default_rng(seed)
    return [_rand(rng, *s) for s in model.op_input_shapes(op, dims)]


# ------------------------------------------------- explicit bwd == autodiff --

def _check_bwd_against_autodiff(op, dims, seed=0, loss_weights=None):
    """<op>_bwd(inputs..., cotangents...) must equal jax.vjp of <op>_fwd."""
    fwd = model.op_builder(op + "_fwd", "xla")
    bwd = model.op_builder(op + "_bwd", "xla")
    ins = _inputs_for(op + "_fwd", dims, seed)
    outs, vjp = jax.vjp(fwd, *ins)
    rng = np.random.default_rng(seed + 1)
    cots = tuple(_rand(rng, *o.shape) for o in outs)
    expected = vjp(cots)
    got = bwd(*ins, *cots)
    assert len(got) == len(expected)
    for g, e in zip(got, expected):
        _close(g, e)


@settings(max_examples=8, deadline=None)
@given(seed=SEEDS)
def test_linear_bwd_autodiff(seed):
    _check_bwd_against_autodiff("linear", dict(b=5, i=13, o=7), seed)


@settings(max_examples=8, deadline=None)
@given(seed=SEEDS)
def test_linear_relu_bwd_autodiff(seed):
    _check_bwd_against_autodiff("linear_relu", dict(b=5, i=13, o=7), seed)


@settings(max_examples=8, deadline=None)
@given(seed=SEEDS)
def test_matmul_bwd_autodiff(seed):
    _check_bwd_against_autodiff("matmul", dict(b=3, i=11, o=9), seed)


@settings(max_examples=6, deadline=None)
@given(seed=SEEDS)
def test_lstm_leaf_bwd_autodiff(seed):
    _check_bwd_against_autodiff("lstm_leaf", dict(b=4, i=10, h=6), seed)


@settings(max_examples=6, deadline=None)
@given(seed=SEEDS)
def test_lstm_branch_bwd_autodiff(seed):
    _check_bwd_against_autodiff("lstm_branch", dict(b=2, h=6), seed)


@settings(max_examples=6, deadline=None)
@given(seed=SEEDS)
def test_gru_bwd_autodiff(seed):
    _check_bwd_against_autodiff("gru", dict(b=4, i=10, h=6), seed)


def test_xent_bwd_is_grad_of_fwd():
    # bwd emits per-row gradients (= count * grad of the mean loss)
    rng = np.random.default_rng(7)
    logits = _rand(rng, 6, 5)
    labels = rng.integers(0, 5, size=6)
    onehot = jnp.asarray(np.eye(5, dtype=np.float32)[labels])
    bwd = model.op_builder("xent_bwd", "xla")
    g_auto = jax.grad(
        lambda l: model.op_builder("xent_fwd", "xla")(l, onehot)[0].reshape(()))(logits)
    _close(bwd(logits, onehot)[0], 6.0 * g_auto)


# ------------------------------------------------------ flavor agreement ----

FLAVORED = [
    ("linear_fwd", dict(b=5, i=13, o=7)),
    ("linear_relu_fwd", dict(b=5, i=13, o=7)),
    ("linear_bwd", dict(b=5, i=13, o=7)),
    ("linear_relu_bwd", dict(b=5, i=13, o=7)),
    ("matmul_fwd", dict(b=3, i=11, o=9)),
    ("matmul_bwd", dict(b=3, i=11, o=9)),
    ("lstm_leaf_fwd", dict(b=4, i=10, h=6)),
    ("lstm_branch_fwd", dict(b=2, h=6)),
    ("gru_fwd", dict(b=4, i=10, h=6)),
]


@pytest.mark.parametrize("op,dims", FLAVORED, ids=[o for o, _ in FLAVORED])
def test_pallas_flavor_matches_xla_flavor(op, dims):
    ins = _inputs_for(op, dims, seed=11)
    out_p = model.op_builder(op, "pallas")(*ins)
    out_x = model.op_builder(op, "xla")(*ins)
    assert len(out_p) == len(out_x)
    for a, b in zip(out_p, out_x):
        _close(a, b)


# --------------------------------------------- padding-invariance (bucket) --

def test_zero_row_padding_is_inert_through_linear_bwd():
    """Bucketed execution pads batch rows with zeros; padded rows must not
    touch parameter gradients (the Rust runtime relies on this)."""
    dims = dict(b=6, i=9, o=4)
    rng = np.random.default_rng(13)
    x = _rand(rng, 6, 9)
    w = _rand(rng, 9, 4)
    b = _rand(rng, 4)
    dy = _rand(rng, 6, 4)
    x_pad = jnp.concatenate([x, jnp.zeros((2, 9))]).astype(jnp.float32)
    dy_pad = jnp.concatenate([dy, jnp.zeros((2, 4))]).astype(jnp.float32)
    bwd = model.op_builder("linear_bwd", "xla")
    dx, dw, db = bwd(x, w, b, dy)
    dxp, dwp, dbp = bwd(x_pad, w, b, dy_pad)
    _close(dwp, dw)
    _close(dbp, db)
    _close(dxp[:6], dx)
    assert np.all(np.asarray(dxp)[6:] == 0.0)


def test_zero_row_padding_is_inert_through_gru_bwd():
    dims = dict(b=3, i=5, h=4)
    ins = _inputs_for("gru_fwd", dims, seed=17)
    m, h, w, u, b = ins
    rng = np.random.default_rng(18)
    dh = _rand(rng, 3, 4)
    bwd = model.op_builder("gru_bwd", "xla")
    base = bwd(m, h, w, u, b, dh)
    mp = jnp.concatenate([m, jnp.zeros((2, 5))]).astype(jnp.float32)
    hp = jnp.concatenate([h, jnp.zeros((2, 4))]).astype(jnp.float32)
    dhp = jnp.concatenate([dh, jnp.zeros((2, 4))]).astype(jnp.float32)
    padded = bwd(mp, hp, w, u, b, dhp)
    _close(padded[2], base[2])  # dw
    _close(padded[3], base[3])  # du
    _close(padded[4], base[4])  # db
    _close(padded[0][:3], base[0])
    _close(padded[1][:3], base[1])
