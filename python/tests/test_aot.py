"""AOT pipeline checks: variant table sanity, name stability, HLO text
emission, and manifest schema (the contract the Rust runtime parses)."""

import json
import os
import re

import pytest

from compile import aot, model


def test_variant_table_unique_names():
    vs = aot.variant_table()
    names = [aot.variant_name(v) for v in vs]
    assert len(names) == len(set(names))
    assert len(names) > 80  # comprehensive coverage of the experiment grid


def test_variant_names_are_filesystem_safe():
    for v in aot.variant_table():
        assert re.fullmatch(r"[a-z0-9_]+", aot.variant_name(v))


def test_every_variant_has_shapes():
    for v in aot.variant_table():
        shapes = model.op_input_shapes(v["op"], v["dims"])
        assert all(all(d >= 1 for d in s) for s in shapes)


def test_lower_variant_emits_hlo_text():
    v = {"op": "xent_fwd", "flavor": "xla", "dims": {"b": 4, "c": 3}}
    text, ins, outs = aot.lower_variant(v)
    assert "HloModule" in text
    assert "ENTRY" in text
    assert ins == [[4, 3], [4, 3]]
    assert outs == [[1, 1], [4, 3]]


def test_lower_pallas_variant_emits_hlo_text():
    v = {"op": "linear_fwd", "flavor": "pallas", "dims": {"b": 4, "i": 6, "o": 3}}
    text, _, _ = aot.lower_variant(v)
    assert "HloModule" in text
    # interpret-mode pallas must lower to plain HLO (no Mosaic custom-call)
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built",
)
def test_built_manifest_matches_table():
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
    with open(path) as f:
        manifest = json.load(f)["artifacts"]
    by_name = {m["name"]: m for m in manifest}
    for v in aot.variant_table():
        name = aot.variant_name(v)
        assert name in by_name, f"missing artifact {name}"
        m = by_name[name]
        assert m["inputs"] == [list(s) for s in model.op_input_shapes(v["op"], v["dims"])]
        art = os.path.join(os.path.dirname(path), m["file"])
        assert os.path.exists(art)
