"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

Hypothesis sweeps shapes (including non-tile-aligned ones, which exercise
the padding path) and value distributions. interpret=True means these run
the exact HLO the Rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gates, linear, ref

jax.config.update("jax_platform_name", "cpu")

DIMS = st.integers(min_value=1, max_value=70)
SMALL = st.integers(min_value=1, max_value=12)
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def _rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


def _close(a, b, rtol=1e-4, atol=1e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol)


# ---------------------------------------------------------------- linear ----

@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=SEEDS)
def test_linear_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _rand(rng, m, k), _rand(rng, k, n), _rand(rng, n)
    _close(linear.linear(x, w, b), ref.linear(x, w, b), rtol=1e-3, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=SEEDS)
def test_linear_relu_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _rand(rng, m, k), _rand(rng, k, n), _rand(rng, n)
    _close(linear.linear_relu(x, w, b), ref.linear_relu(x, w, b),
           rtol=1e-3, atol=1e-4)


def test_linear_tile_aligned_exact_shapes():
    # 128-aligned: no padding path at all
    rng = np.random.default_rng(0)
    x, w, b = _rand(rng, 128, 256), _rand(rng, 256, 128), _rand(rng, 128)
    _close(linear.linear(x, w, b), ref.linear(x, w, b), rtol=1e-3, atol=1e-3)


def test_linear_large_k_accumulation():
    # multiple K steps with accumulation across grid iterations
    rng = np.random.default_rng(1)
    x, w, b = _rand(rng, 16, 784, scale=0.1), _rand(rng, 784, 10, scale=0.1), _rand(rng, 10)
    _close(linear.linear(x, w, b), ref.linear(x, w, b), rtol=1e-3, atol=1e-4)


def test_matmul_zero_bias():
    rng = np.random.default_rng(2)
    x, w = _rand(rng, 3, 5), _rand(rng, 5, 7)
    _close(linear.matmul(x, w), x @ w)


# ----------------------------------------------------------------- gates ----

@settings(max_examples=20, deadline=None)
@given(b=SMALL, h=DIMS, seed=SEEDS)
def test_lstm_leaf_gates_match_ref(b, h, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, b, 8)
    w = _rand(rng, 8, 3 * h, scale=0.5)
    bb = _rand(rng, 3 * h)
    h_ref, c_ref = ref.lstm_leaf(x, w, bb)
    g = x @ w + bb
    h_pl, c_pl = gates.lstm_leaf_gates(g)
    _close(h_pl, h_ref)
    _close(c_pl, c_ref)


@settings(max_examples=20, deadline=None)
@given(b=SMALL, h=DIMS, seed=SEEDS)
def test_lstm_branch_gates_match_ref(b, h, seed):
    rng = np.random.default_rng(seed)
    hl, cl, hr, cr = (_rand(rng, b, h) for _ in range(4))
    w = _rand(rng, 2 * h, 5 * h, scale=0.3)
    bb = _rand(rng, 5 * h)
    h_ref, c_ref = ref.lstm_branch(hl, cl, hr, cr, w, bb)
    g = jnp.concatenate([hl, hr], axis=1) @ w + bb
    h_pl, c_pl = gates.lstm_branch_gates(g, cl, cr)
    _close(h_pl, h_ref)
    _close(c_pl, c_ref)


@settings(max_examples=20, deadline=None)
@given(b=SMALL, i=DIMS, h=DIMS, seed=SEEDS)
def test_gru_gates_match_ref(b, i, h, seed):
    rng = np.random.default_rng(seed)
    m, hh = _rand(rng, b, i), _rand(rng, b, h)
    w = _rand(rng, i, 3 * h, scale=0.3)
    u = _rand(rng, h, 3 * h, scale=0.3)
    bb = _rand(rng, 3 * h)
    out_ref = ref.gru(m, hh, w, u, bb)
    out_pl = gates.gru_gates(m @ w + bb, hh @ u, hh)
    _close(out_pl, out_ref)


# --------------------------------------------------------- loss oracles -----

def test_xent_matches_jax_grad():
    # fwd loss is the mean over rows; xent_grad is per-row (sum) gradient:
    # per-row grad == count * grad(mean loss)  — the accumulator averages.
    rng = np.random.default_rng(3)
    logits = _rand(rng, 6, 5)
    labels = rng.integers(0, 5, size=6)
    onehot = jnp.asarray(np.eye(5, dtype=np.float32)[labels])
    loss, probs = ref.xent(logits, onehot)
    g_analytic = ref.xent_grad(logits, onehot)
    g_auto = jax.grad(lambda l: ref.xent(l, onehot)[0].reshape(()))(logits)
    _close(g_analytic, 6.0 * g_auto)
    _close(jnp.sum(probs, axis=1), jnp.ones(6))


def test_xent_padding_rows_are_inert():
    """Padding rows (all-zero one-hot) contribute no loss and no gradient."""
    rng = np.random.default_rng(4)
    logits = _rand(rng, 4, 3)
    onehot = jnp.asarray(
        np.array([[1, 0, 0], [0, 1, 0], [0, 0, 0], [0, 0, 0]], np.float32))
    loss_pad, _ = ref.xent(logits, onehot)
    loss_real, _ = ref.xent(logits[:2], onehot[:2])
    _close(loss_pad, loss_real)
    g = ref.xent_grad(logits, onehot)
    assert np.all(np.asarray(g)[2:] == 0.0)


def test_mse_padding_rows_are_inert():
    rng = np.random.default_rng(5)
    pred, target = _rand(rng, 4, 2), _rand(rng, 4, 2)
    mask = jnp.asarray(np.array([[1], [1], [0], [0]], np.float32))
    loss_pad, _ = ref.mse(pred, target, mask)
    loss_real, _ = ref.mse(pred[:2], target[:2], mask[:2])
    _close(loss_pad, loss_real)
    g = ref.mse_grad(pred, target, mask)
    assert np.all(np.asarray(g)[2:] == 0.0)
    g_auto = jax.grad(lambda p: ref.mse(p, target, mask)[0].reshape(()))(pred)
    _close(g, 2.0 * g_auto)  # per-row grad = count * grad(mean loss)
