"""L1 Pallas kernel: tiled matmul + bias + optional activation.

This is the compute hot-spot of every AMPNet model (the paper's premise is
that per-node cost is dominated by the dense `x @ W` of each parameterized
payload-transform node). The kernel is written TPU-first:

* the grid is (M/bm, N/bn, K/bk) with K innermost, so each (bm, bn) output
  tile stays resident in VMEM while weight tiles stream through the MXU;
* blocks default to 128x128 — the MXU native tile — and shrink to the
  (padded) problem size for the small dimensions of dynamic-network cells;
* `jnp.dot(..., preferred_element_type=jnp.float32)` accumulates in f32 so
  bf16 operands would use the MXU's native accumulation on real hardware;
* bias-add and the activation are fused into the last K step: one VPU pass
  over the output tile while it is still in VMEM.

On this CPU-only image the kernel must run with `interpret=True` (real TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute); the
structure above is what the DESIGN.md TPU performance estimate is based on.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget notes (per output tile, f32):
#   x tile bm*bk + w tile bk*bn + out tile bm*bn = 3 * 128^2 * 4B = 192 KiB
# comfortably inside a TPU core's ~16 MiB VMEM even with double buffering.
DEFAULT_BLOCK = 128


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _block(dim: int, cap: int = DEFAULT_BLOCK) -> int:
    """Block size for a dimension: the MXU tile, shrunk for small dims."""
    if dim >= cap:
        return cap
    # next power of two >= dim keeps interpret-mode masking simple
    b = 1
    while b < dim:
        b *= 2
    return b


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, k_steps: int, act: str):
    """Grid (i, j, k); K innermost. o tile is revisited across k."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finish():
        y = o_ref[...] + b_ref[...]
        if act == "relu":
            y = jnp.maximum(y, 0.0)
        elif act == "tanh":
            y = jnp.tanh(y)
        o_ref[...] = y


@functools.partial(jax.jit, static_argnames=("act",))
def matmul_bias_act(x, w, b, act: str = "none"):
    """y = act(x @ w + b) via the tiled Pallas kernel.

    x: [M, K], w: [K, N], b: [N]. Arbitrary (static) shapes; inputs are
    zero-padded up to block multiples and the result is sliced back.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    bm, bn, bk = _block(m), _block(n), _block(k)
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    bp = jnp.pad(b, (0, np_ - n))
    k_steps = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps, act=act),
        grid=(mp // bm, np_ // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, wp, bp)
    return out[:m, :n]


def linear(x, w, b):
    """Pallas flavor of ref.linear."""
    return matmul_bias_act(x, w, b, act="none")


def linear_relu(x, w, b):
    """Pallas flavor of ref.linear_relu (fused activation)."""
    return matmul_bias_act(x, w, b, act="relu")


def matmul(x, w):
    """Pallas flavor of ref.matmul (zero bias)."""
    return matmul_bias_act(x, w, jnp.zeros((w.shape[1],), jnp.float32))
