"""Pure-jnp reference oracles for every Pallas kernel and every AOT op.

These are the single source of truth for the math. The Pallas kernels
(`linear.py`, `gates.py`) are checked against these in `python/tests/`, and
the Rust native backend re-implements the same formulas (checked against the
XLA artifacts in rust integration tests). Everything is f32.

Conventions
-----------
* `linear`: y = x @ w + b, x:[B,I], w:[I,O], b:[O].
* `lstm_leaf`: 3 gates (i, o, u) from the token embedding only; c_prev = 0.
  g = x @ w + b, g:[B,3H];  i,o = sigmoid;  u = tanh;  c = i*u; h = o*tanh(c)
* `lstm_branch`: 5 gates (i, fl, fr, o, u) from the concatenated child
  hidden states, with per-child forget gates (Tai et al. 2015, binary tree):
  g = [hl, hr] @ w + b, g:[B,5H];  c = fl*cl + fr*cr + i*u;  h = o*tanh(c)
* `gru` (GGSNN propagation cell, Li et al. 2015 / Cho et al. 2014):
  xw = m @ w + b  (3H);  hu = h @ u  (3H)
  z = sigmoid(xw_z + hu_z); r = sigmoid(xw_r + hu_r)
  n = tanh(xw_n + r * hu_n);  h' = (1 - z) * h + z * n
* `xent`: padding-safe softmax cross entropy. Rows whose one-hot target is
  all-zero are padding: they contribute no loss and no gradient. The loss is
  averaged over *real* rows.
* `mse`: padding-safe masked mean-squared error (mask:[B,1] in {0,1}).
"""

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- linear ----

def linear(x, w, b):
    return x @ w + b


def linear_relu(x, w, b):
    return jax.nn.relu(x @ w + b)


def relu(x):
    return jax.nn.relu(x)


def matmul(x, w):
    return x @ w


# ------------------------------------------------------------------ lstm ----

def lstm_leaf(x, w, b):
    """Leaf LSTM cell. Returns (h, c)."""
    h_dim = w.shape[1] // 3
    g = x @ w + b
    i = jax.nn.sigmoid(g[:, :h_dim])
    o = jax.nn.sigmoid(g[:, h_dim : 2 * h_dim])
    u = jnp.tanh(g[:, 2 * h_dim :])
    c = i * u
    h = o * jnp.tanh(c)
    return h, c


def lstm_branch(hl, cl, hr, cr, w, b):
    """Branch LSTM cell over two children. Returns (h, c)."""
    h_dim = w.shape[1] // 5
    g = jnp.concatenate([hl, hr], axis=1) @ w + b
    i = jax.nn.sigmoid(g[:, :h_dim])
    fl = jax.nn.sigmoid(g[:, h_dim : 2 * h_dim])
    fr = jax.nn.sigmoid(g[:, 2 * h_dim : 3 * h_dim])
    o = jax.nn.sigmoid(g[:, 3 * h_dim : 4 * h_dim])
    u = jnp.tanh(g[:, 4 * h_dim :])
    c = fl * cl + fr * cr + i * u
    h = o * jnp.tanh(c)
    return h, c


# ------------------------------------------------------------------- gru ----

def gru(m, h, w, u, b):
    """GGSNN propagation GRU. m:[B,I] incoming message, h:[B,H]. Returns h'."""
    h_dim = h.shape[1]
    xw = m @ w + b          # [B, 3H]
    hu = h @ u              # [B, 3H]
    z = jax.nn.sigmoid(xw[:, :h_dim] + hu[:, :h_dim])
    r = jax.nn.sigmoid(xw[:, h_dim : 2 * h_dim] + hu[:, h_dim : 2 * h_dim])
    n = jnp.tanh(xw[:, 2 * h_dim :] + r * hu[:, 2 * h_dim :])
    return (1.0 - z) * h + z * n


# ---------------------------------------------------------------- losses ----

def xent(logits, onehot):
    """Padding-safe softmax cross-entropy.

    Returns (loss:[1,1], probs:[B,C]). Rows with all-zero one-hot are
    padding and contribute nothing; loss is the mean over real rows.
    """
    lse = jax.scipy.special.logsumexp(logits, axis=1, keepdims=True)
    logp = logits - lse
    rowmask = jnp.sum(onehot, axis=1, keepdims=True)          # [B,1] in {0,1}
    count = jnp.maximum(jnp.sum(rowmask), 1.0)
    loss = -jnp.sum(onehot * logp) / count
    probs = jnp.exp(logp)
    return loss.reshape(1, 1), probs


def xent_grad(logits, onehot):
    """Per-row gradient: d(row loss)/d logits = probs - onehot.

    Deliberately NOT divided by the row count: AMPNet's gradient
    accumulators (`optim::ParamSet`) average over the number of
    accumulated row-gradients at update time, so the loss layer emits
    per-example gradients (padding rows still get exactly zero).
    """
    lse = jax.scipy.special.logsumexp(logits, axis=1, keepdims=True)
    probs = jnp.exp(logits - lse)
    rowmask = jnp.sum(onehot, axis=1, keepdims=True)
    return rowmask * (probs - onehot)


def mse(pred, target, mask):
    """Masked MSE. pred,target:[B,O], mask:[B,1]. Returns (loss:[1,1], diff)."""
    diff = (pred - target) * mask
    count = jnp.maximum(jnp.sum(mask), 1.0) * pred.shape[1]
    loss = jnp.sum(diff * diff) / count
    return loss.reshape(1, 1), diff


def mse_grad(pred, target, mask):
    """Per-row gradient of the row-mean-squared error (see xent_grad for
    the accumulator-side averaging convention)."""
    diff = (pred - target) * mask
    return 2.0 * diff / pred.shape[1]
