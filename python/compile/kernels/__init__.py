from . import gates, linear, ref  # noqa: F401
