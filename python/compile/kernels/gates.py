"""L1 Pallas kernels: fused recurrent-cell gate nonlinearities.

After the matmuls of an LSTM/GRU cell produce the pre-activation gate
matrix, the remaining work is a chain of element-wise ops (sigmoid/tanh/
mul/add). On a TPU these belong in one fused VPU pass over the gate tile
while it is still in VMEM — exactly what these kernels express. Each kernel
processes the whole (small) cell state as a single block: for the largest
configuration in this repo (B=128, H=128, 5 gates) that is
5*128*128*4B = 320 KiB of VMEM, far below budget.

Checked against `ref.py` in python/tests/test_kernels.py; `interpret=True`
for CPU execution (see linear.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sigmoid(x):
    return 1.0 / (1.0 + jnp.exp(-x))


# ------------------------------------------------------------- lstm leaf ----

def _lstm_leaf_kernel(g_ref, h_ref, c_ref):
    h_dim = h_ref.shape[1]
    g = g_ref[...]
    i = _sigmoid(g[:, :h_dim])
    o = _sigmoid(g[:, h_dim : 2 * h_dim])
    u = jnp.tanh(g[:, 2 * h_dim :])
    c = i * u
    c_ref[...] = c
    h_ref[...] = o * jnp.tanh(c)


def lstm_leaf_gates(g):
    """g:[B,3H] pre-activation gates -> (h, c), each [B,H]."""
    b, g3 = g.shape
    h_dim = g3 // 3
    shp = jax.ShapeDtypeStruct((b, h_dim), jnp.float32)
    return pl.pallas_call(
        _lstm_leaf_kernel, out_shape=(shp, shp), interpret=True
    )(g)


# ----------------------------------------------------------- lstm branch ----

def _lstm_branch_kernel(g_ref, cl_ref, cr_ref, h_ref, c_ref):
    h_dim = h_ref.shape[1]
    g = g_ref[...]
    i = _sigmoid(g[:, :h_dim])
    fl = _sigmoid(g[:, h_dim : 2 * h_dim])
    fr = _sigmoid(g[:, 2 * h_dim : 3 * h_dim])
    o = _sigmoid(g[:, 3 * h_dim : 4 * h_dim])
    u = jnp.tanh(g[:, 4 * h_dim :])
    c = fl * cl_ref[...] + fr * cr_ref[...] + i * u
    c_ref[...] = c
    h_ref[...] = o * jnp.tanh(c)


def lstm_branch_gates(g, cl, cr):
    """g:[B,5H], cl/cr:[B,H] child cell states -> (h, c)."""
    b, h_dim = cl.shape
    shp = jax.ShapeDtypeStruct((b, h_dim), jnp.float32)
    return pl.pallas_call(
        _lstm_branch_kernel, out_shape=(shp, shp), interpret=True
    )(g, cl, cr)


# ------------------------------------------------------------------- gru ----

def _gru_kernel(xw_ref, hu_ref, h_ref, o_ref):
    h_dim = h_ref.shape[1]
    xw = xw_ref[...]
    hu = hu_ref[...]
    h = h_ref[...]
    z = _sigmoid(xw[:, :h_dim] + hu[:, :h_dim])
    r = _sigmoid(xw[:, h_dim : 2 * h_dim] + hu[:, h_dim : 2 * h_dim])
    n = jnp.tanh(xw[:, 2 * h_dim :] + r * hu[:, 2 * h_dim :])
    o_ref[...] = (1.0 - z) * h + z * n


def gru_gates(xw, hu, h):
    """xw:[B,3H] = m@W+b, hu:[B,3H] = h@U, h:[B,H] -> h':[B,H]."""
    b, h_dim = h.shape
    return pl.pallas_call(
        _gru_kernel,
        out_shape=jax.ShapeDtypeStruct((b, h_dim), jnp.float32),
        interpret=True,
    )(xw, hu, h)
