"""AOT lowering: every (op, dims, flavor) variant needed by the experiment
configs is lowered once to HLO *text* plus a manifest the Rust runtime reads.

HLO text — NOT ``lowered.compiler_ir("hlo").as_hlo_text()`` via serialized
protos — is the interchange format: jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs (in --out, default ../artifacts):
  <name>.hlo.txt   one per variant;  name = op__<dims>__<flavor>
  manifest.json    [{name, op, flavor, dims, inputs, outputs, file}, ...]

Python runs ONLY here (build time). ``make artifacts`` is incremental at the
directory level; re-run with --force to rebuild.

Usage:  python -m compile.aot --out ../artifacts [--filter REGEX] [--force]
"""

import argparse
import json
import os
import re
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# --------------------------------------------------------------- configs ----
# Shape configurations per experiment (see DESIGN.md §6). Bucketed batch
# dims: variable-cardinality message groups (tree leaves, edges per type,
# nodes per graph) are padded up to the nearest bucket by the Rust runtime.

EDGE_BUCKETS = [1, 4, 16, 64]
QM9_NODE_BUCKETS = [8, 16, 32]


def _v(op, flavor, **dims):
    return {"op": op, "flavor": flavor, "dims": dims}


def variant_table():
    vs = []

    def both(op, **dims):
        """xla flavor always; pallas flavor for the kernel-bearing ops."""
        vs.append(_v(op, "xla", **dims))
        kernel_ops = (
            "linear_fwd", "linear_relu_fwd", "linear_bwd", "linear_relu_bwd",
            "matmul_fwd", "matmul_bwd",
            "lstm_leaf_fwd", "lstm_branch_fwd", "gru_fwd",
        )
        if op in kernel_ops:
            vs.append(_v(op, "pallas", **dims))

    # ---- MLP / MNIST-like (B=100, 784-784-784-10) --------------------------
    both("linear_relu_fwd", b=100, i=784, o=784)
    both("linear_relu_bwd", b=100, i=784, o=784)
    both("linear_fwd", b=100, i=784, o=10)
    both("linear_bwd", b=100, i=784, o=10)
    both("xent_fwd", b=100, c=10)
    both("xent_bwd", b=100, c=10)

    # ---- RNN / list reduction (B=100, E=128, H=128, V=16, 10 classes) ------
    # embedding lookup + concat are native (memory-bound); the loop body is
    # Linear-1 = linear_relu over the concatenated [embed, h].
    both("linear_relu_fwd", b=100, i=256, o=128)
    both("linear_relu_bwd", b=100, i=256, o=128)
    both("linear_fwd", b=100, i=128, o=10)
    both("linear_bwd", b=100, i=128, o=10)
    both("xent_fwd", b=100, c=10)   # dedup'd below
    both("xent_bwd", b=100, c=10)

    # ---- Tree-LSTM / sentiment (E=128, H=128, 5 classes) -------------------
    # leaves are grouped (paper: "only grouping the leaf operations"),
    # branches and heads run at B=1.
    for b in EDGE_BUCKETS:
        both("lstm_leaf_fwd", b=b, i=128, h=128)
        both("lstm_leaf_bwd", b=b, i=128, h=128)
    both("lstm_branch_fwd", b=1, h=128)
    both("lstm_branch_bwd", b=1, h=128)
    both("linear_fwd", b=1, i=128, o=5)
    both("linear_bwd", b=1, i=128, o=5)
    both("xent_fwd", b=1, c=5)
    both("xent_bwd", b=1, c=5)

    # ---- TF-Fold-style tree baseline: depth-batched cells ------------------
    # (dynamic batching merges same-depth ops across a 100-tree minibatch)
    for b in [256, 1024, 2048]:
        both("lstm_leaf_fwd", b=b, i=128, h=128)
        both("lstm_leaf_bwd", b=b, i=128, h=128)
    for b in [4, 16, 64, 256]:
        both("lstm_branch_fwd", b=b, h=128)
        both("lstm_branch_bwd", b=b, h=128)
    for b in [64, 256, 1024, 4096]:
        both("linear_fwd", b=b, i=128, o=5)
        both("linear_bwd", b=b, i=128, o=5)
        both("xent_fwd", b=b, c=5)
        both("xent_bwd", b=b, c=5)

    # ---- GGSNN / bAbI-15 (N=54 pad 64, H=5, C_edge=2 used of 4) ------------
    for b in EDGE_BUCKETS:
        both("linear_fwd", b=b, i=5, o=5)
        both("linear_bwd", b=b, i=5, o=5)
    both("gru_fwd", b=64, i=5, h=5)
    both("gru_bwd", b=64, i=5, h=5)
    both("linear_fwd", b=64, i=5, o=1)   # per-node score head
    both("linear_bwd", b=64, i=5, o=1)
    both("xent_fwd", b=1, c=64)          # softmax over (padded) nodes
    both("xent_bwd", b=1, c=64)

    # ---- GGSNN / QM9-like (N<=29, H=100, 4 edge types, regression) ---------
    for b in EDGE_BUCKETS:
        both("linear_fwd", b=b, i=100, o=100)
        both("linear_bwd", b=b, i=100, o=100)
    for b in QM9_NODE_BUCKETS:
        both("gru_fwd", b=b, i=100, h=100)
        both("gru_bwd", b=b, i=100, h=100)
    both("linear_fwd", b=1, i=100, o=1)  # regression head on summed states
    both("linear_bwd", b=1, i=100, o=1)
    both("mse_fwd", b=1, o=1)
    both("mse_bwd", b=1, o=1)

    # ---- dense TF-style GGSNN baseline: h' = h_flat @ A (NH x NH) ----------
    both("matmul_fwd", b=1, i=270, o=270)       # bAbI: 54*5, padded to 270
    both("matmul_bwd", b=1, i=270, o=270)
    for n in QM9_NODE_BUCKETS:
        both("matmul_fwd", b=1, i=100 * n, o=100 * n)
        both("matmul_bwd", b=1, i=100 * n, o=100 * n)

    # dedup (several models share shapes)
    seen, out = set(), []
    for v in vs:
        key = (v["op"], v["flavor"], tuple(sorted(v["dims"].items())))
        if key not in seen:
            seen.add(key)
            out.append(v)
    return out


# -------------------------------------------------------------- lowering ----

def variant_name(v):
    dims = "_".join(f"{k}{val}" for k, val in sorted(v["dims"].items()))
    return f"{v['op']}__{dims}__{v['flavor']}"


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(v):
    """Returns (hlo_text, input_shapes, output_shapes)."""
    fn = model.op_builder(v["op"], v["flavor"])
    in_shapes = model.op_input_shapes(v["op"], v["dims"])
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in in_shapes]
    # keep_unused: some backward ops have arguments that are mathematically
    # unused (e.g. the bias in linear_bwd); the Rust runtime supplies every
    # manifest input, so the HLO entry must keep every parameter.
    lowered = jax.jit(fn, keep_unused=True).lower(*specs)
    outs = [
        tuple(int(d) for d in o.shape)
        for o in jax.eval_shape(fn, *specs)
    ]
    return to_hlo_text(lowered), [list(s) for s in in_shapes], [list(s) for s in outs]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--filter", default=None, help="regex on variant name")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    vs = variant_table()
    if args.filter:
        rx = re.compile(args.filter)
        vs = [v for v in vs if rx.search(variant_name(v))]

    manifest = []
    n_written = n_skipped = 0
    for v in vs:
        name = variant_name(v)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out, fname)
        try:
            if args.force or not os.path.exists(path):
                text, ins, outs = lower_variant(v)
                with open(path, "w") as f:
                    f.write(text)
                n_written += 1
            else:
                _, ins, outs = (
                    None,
                    [list(s) for s in model.op_input_shapes(v["op"], v["dims"])],
                    [tuple(int(d) for d in o.shape) for o in jax.eval_shape(
                        model.op_builder(v["op"], v["flavor"]),
                        *[jax.ShapeDtypeStruct(s, jnp.float32)
                          for s in model.op_input_shapes(v["op"], v["dims"])])],
                )
                outs = [list(o) for o in outs]
                n_skipped += 1
        except Exception as e:  # pragma: no cover - surfaced at build time
            print(f"FAILED {name}: {e}", file=sys.stderr)
            raise
        manifest.append({
            "name": name, "op": v["op"], "flavor": v["flavor"],
            "dims": v["dims"], "inputs": ins, "outputs": outs, "file": fname,
        })
        print(f"  {name}  in={ins} out={outs}")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump({"artifacts": manifest}, f, indent=1)
    print(f"aot: {n_written} lowered, {n_skipped} cached, "
          f"{len(manifest)} total -> {args.out}/manifest.json")


if __name__ == "__main__":
    main()
