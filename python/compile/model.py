"""L2: the per-IR-node compute graphs, as jax functions with explicit
forward/backward pairs.

AMPNet's IR moves *messages* between nodes; each parameterized payload-
transform (PPT) node owns its parameters and runs two programs: a forward
transform and a backward transform. This module defines those programs for
every node type used by the paper's models. Each op exists in two flavors:

* ``pallas`` — matmuls and gate nonlinearities go through the L1 Pallas
  kernels (`kernels/linear.py`, `kernels/gates.py`); this is the flavor
  whose *structure* matches the TPU deployment story (DESIGN.md §Perf).
* ``xla``    — the same math in plain jnp (`kernels/ref.py`), which XLA's
  CPU backend compiles to tight Eigen loops; this is the fast flavor under
  CPU execution and is bit-checked against ``pallas`` in python/tests.

Backward convention: ``<op>_bwd`` takes the forward op's *inputs* followed
by the cotangents of its outputs, and returns the cotangents of every
forward input (data inputs first, then parameters). The Rust PPT node
caches forward inputs keyed by message state (the paper's "activation
recorded by keying on the state") and feeds them back here. LSTM/GRU
backwards are derived with ``jax.vjp`` over the reference math — the
recompute-inside-bwd cost matches the paper's Appendix C assumption that a
backward step costs ~3x a forward step.

Loss ops are the exception: their backward is analytic and takes no
cotangent (d loss / d loss = 1).
"""

import jax
import jax.numpy as jnp

from .kernels import gates, linear as plin, ref


def _mm(flavor):
    """Matmul-with-bias primitive for a flavor."""
    if flavor == "pallas":
        return plin.matmul_bias_act
    def xla_mm(x, w, b, act="none"):
        y = x @ w + b
        if act == "relu":
            y = jnp.maximum(y, 0.0)
        elif act == "tanh":
            y = jnp.tanh(y)
        return y
    return xla_mm


# ================================================================ linear ====

def linear_fwd(flavor):
    def fwd(x, w, b):
        return (_mm(flavor)(x, w, b, "none"),)
    return fwd


def linear_relu_fwd(flavor):
    def fwd(x, w, b):
        return (_mm(flavor)(x, w, b, "relu"),)
    return fwd


def linear_bwd(flavor):
    """(x, w, b, dy) -> (dx, dw, db). Explicit formulas, Pallas matmuls."""
    mm = _mm(flavor)
    def bwd(x, w, b, dy):
        zn = jnp.zeros((w.shape[0],), jnp.float32)
        zi = jnp.zeros((dy.shape[1],), jnp.float32)
        dx = mm(dy, w.T, zn, "none")
        dw = mm(x.T, dy, zi, "none")
        db = jnp.sum(dy, axis=0)
        return dx, dw, db
    return bwd


def linear_relu_bwd(flavor):
    """(x, w, b, dy) -> (dx, dw, db); recomputes the preactivation mask."""
    mm = _mm(flavor)
    def bwd(x, w, b, dy):
        pre = mm(x, w, b, "none")
        dy = dy * (pre > 0.0).astype(jnp.float32)
        zn = jnp.zeros((w.shape[0],), jnp.float32)
        zi = jnp.zeros((dy.shape[1],), jnp.float32)
        dx = mm(dy, w.T, zn, "none")
        dw = mm(x.T, dy, zi, "none")
        db = jnp.sum(dy, axis=0)
        return dx, dw, db
    return bwd


def matmul_fwd(flavor):
    """Bias-free matmul: the dense `h @ A` propagation of the TF-style
    GGSNN baseline (A is the per-instance NHxNH block-adjacency matrix)."""
    def fwd(x, w):
        if flavor == "pallas":
            return (plin.matmul(x, w),)
        return (x @ w,)
    return fwd


def matmul_bwd(flavor):
    def bwd(x, w, dy):
        if flavor == "pallas":
            zk = jnp.zeros((w.shape[0],), jnp.float32)
            zn = jnp.zeros((dy.shape[1],), jnp.float32)
            return plin.matmul_bias_act(dy, w.T, zk), plin.matmul_bias_act(x.T, dy, zn)
        return dy @ w.T, x.T @ dy
    return bwd


# ================================================================== lstm ====

def lstm_leaf_fwd(flavor):
    def fwd(x, w, b):
        if flavor == "pallas":
            g = plin.matmul_bias_act(x, w, b, "none")
            return gates.lstm_leaf_gates(g)
        return ref.lstm_leaf(x, w, b)
    return fwd


def lstm_leaf_bwd(flavor):
    def bwd(x, w, b, dh, dc):
        _, vjp = jax.vjp(ref.lstm_leaf, x, w, b)
        return vjp((dh, dc))
    return bwd


def lstm_branch_fwd(flavor):
    def fwd(hl, cl, hr, cr, w, b):
        if flavor == "pallas":
            g = plin.matmul_bias_act(
                jnp.concatenate([hl, hr], axis=1), w, b, "none"
            )
            return gates.lstm_branch_gates(g, cl, cr)
        return ref.lstm_branch(hl, cl, hr, cr, w, b)
    return fwd


def lstm_branch_bwd(flavor):
    def bwd(hl, cl, hr, cr, w, b, dh, dc):
        _, vjp = jax.vjp(ref.lstm_branch, hl, cl, hr, cr, w, b)
        return vjp((dh, dc))
    return bwd


# =================================================================== gru ====

def gru_fwd(flavor):
    def fwd(m, h, w, u, b):
        if flavor == "pallas":
            xw = plin.matmul_bias_act(m, w, b, "none")
            hu = plin.matmul(h, u)
            return (gates.gru_gates(xw, hu, h),)
        return (ref.gru(m, h, w, u, b),)
    return fwd


def gru_bwd(flavor):
    def bwd(m, h, w, u, b, dh_new):
        _, vjp = jax.vjp(ref.gru, m, h, w, u, b)
        return vjp(dh_new)
    return bwd


# ================================================================ losses ====

def xent_fwd(flavor):
    def fwd(logits, onehot):
        return ref.xent(logits, onehot)
    return fwd


def xent_bwd(flavor):
    def bwd(logits, onehot):
        return (ref.xent_grad(logits, onehot),)
    return bwd


def mse_fwd(flavor):
    def fwd(pred, target, mask):
        return ref.mse(pred, target, mask)
    return fwd


def mse_bwd(flavor):
    def bwd(pred, target, mask):
        return (ref.mse_grad(pred, target, mask),)
    return bwd


# ============================================================== registry ====

def op_builder(op: str, flavor: str):
    """Resolve an op name to a jax function builder."""
    table = {
        "linear_fwd": linear_fwd,
        "linear_bwd": linear_bwd,
        "linear_relu_fwd": linear_relu_fwd,
        "linear_relu_bwd": linear_relu_bwd,
        "matmul_fwd": matmul_fwd,
        "matmul_bwd": matmul_bwd,
        "lstm_leaf_fwd": lstm_leaf_fwd,
        "lstm_leaf_bwd": lstm_leaf_bwd,
        "lstm_branch_fwd": lstm_branch_fwd,
        "lstm_branch_bwd": lstm_branch_bwd,
        "gru_fwd": gru_fwd,
        "gru_bwd": gru_bwd,
        "xent_fwd": xent_fwd,
        "xent_bwd": xent_bwd,
        "mse_fwd": mse_fwd,
        "mse_bwd": mse_bwd,
    }
    return table[op](flavor)


def op_input_shapes(op: str, d: dict):
    """Input shapes for an op given its dims dict (b/i/o/h/c as relevant)."""
    b = d.get("b")
    if op in ("linear_fwd", "linear_relu_fwd"):
        return [(b, d["i"]), (d["i"], d["o"]), (d["o"],)]
    if op in ("linear_bwd", "linear_relu_bwd"):
        return [(b, d["i"]), (d["i"], d["o"]), (d["o"],), (b, d["o"])]
    if op == "matmul_fwd":
        return [(b, d["i"]), (d["i"], d["o"])]
    if op == "matmul_bwd":
        return [(b, d["i"]), (d["i"], d["o"]), (b, d["o"])]
    if op == "lstm_leaf_fwd":
        return [(b, d["i"]), (d["i"], 3 * d["h"]), (3 * d["h"],)]
    if op == "lstm_leaf_bwd":
        return [(b, d["i"]), (d["i"], 3 * d["h"]), (3 * d["h"],),
                (b, d["h"]), (b, d["h"])]
    if op == "lstm_branch_fwd":
        h = d["h"]
        return [(b, h), (b, h), (b, h), (b, h), (2 * h, 5 * h), (5 * h,)]
    if op == "lstm_branch_bwd":
        h = d["h"]
        return [(b, h), (b, h), (b, h), (b, h), (2 * h, 5 * h), (5 * h,),
                (b, h), (b, h)]
    if op == "gru_fwd":
        return [(b, d["i"]), (b, d["h"]), (d["i"], 3 * d["h"]),
                (d["h"], 3 * d["h"]), (3 * d["h"],)]
    if op == "gru_bwd":
        return [(b, d["i"]), (b, d["h"]), (d["i"], 3 * d["h"]),
                (d["h"], 3 * d["h"]), (3 * d["h"],), (b, d["h"])]
    if op in ("xent_fwd", "xent_bwd"):
        return [(b, d["c"]), (b, d["c"])]
    if op in ("mse_fwd", "mse_bwd"):
        return [(b, d["o"]), (b, d["o"]), (b, 1)]
    raise KeyError(op)
