//! End-to-end validation driver (EXPERIMENTS.md §E2E): train a real model
//! for a few hundred steps through the full stack — Pallas/JAX AOT
//! artifacts, PJRT execution, the static IR, the asynchronous scheduler —
//! and log the loss curve.
//!
//! The model is the list-reduction RNN with 4 replicas (the paper's most
//! system-intensive configuration: loop control flow + data parallelism +
//! asynchrony). ~400 minibatch instances of 100 sequences = ~40k
//! sequences, several hundred parameter updates per parameterized node.
//!
//!   cargo run --release --example e2e_train [--steps N] [--backend xla]

use ampnet::data::Split;
use ampnet::launcher::{backend_spec, build_model};
use ampnet::scheduler::{sync_replicas, EngineKind, EpochKind};
use ampnet::util::Args;
use anyhow::Result;

fn main() -> Result<()> {
    ampnet::util::logging::init();
    let args = Args::from_env();
    let steps = args.usize_or("steps", 400);
    std::env::set_var("AMP_SCALE", "0.05"); // 5000 train instances available
    // lr 0.3: the async 4-replica configuration is stable here (0.5, the
    // single-replica default, occasionally diverges under staleness)
    let (model, _target) = build_model(
        "rnn",
        &Args::parse(["--replicas".into(), "4".into(), "--lr".into(), "0.3".into()].into_iter()),
        16,
    )?;
    let backend = backend_spec(&args)?;
    let mut engine = ampnet::scheduler::build_engine(EngineKind::Sim, model.graph, backend, false)?;
    let pumper = model.pumper;

    println!("step, train_loss(ema), acc(ema), inst/s(virtual), staleness");
    let mut done = 0usize;
    let chunk = 20usize;
    let mut ema_loss = ampnet::util::stats::Ema::new(0.2);
    let mut ema_acc = ampnet::util::stats::Ema::new(0.2);
    while done < steps {
        let n = chunk.min(steps - done);
        let pumps: Vec<_> = (done..done + n)
            .map(|i| pumper.pump(Split::Train, i % pumper.n(Split::Train)))
            .collect();
        let stats = engine.run_epoch(pumps, 8, EpochKind::Train)?;
        anyhow::ensure!(engine.cached_keys()? == 0, "leaked keys");
        sync_replicas(engine.as_mut(), &model.replica_groups)?;
        done += n;
        let l = ema_loss.update(stats.mean_loss());
        let a = ema_acc.update(stats.accuracy());
        println!(
            "{done:>5}, {l:>14.4}, {a:>8.3}, {:>14.1}, {:>9.2}",
            stats.throughput(),
            stats.mean_staleness()
        );
    }
    // final validation pass
    let pumps: Vec<_> = (0..pumper.n(Split::Valid).min(20))
        .map(|i| pumper.pump(Split::Valid, i))
        .collect();
    let v = engine.run_epoch(pumps, 8, EpochKind::Eval)?;
    println!("final validation accuracy over {} sequences: {:.4}", v.count, v.accuracy());
    ampnet::launcher::maybe_write_json(
        "e2e_train",
        &ampnet::util::json::obj(vec![
            ("steps", ampnet::util::json::num(done as f64)),
            ("loss_ema", ampnet::util::json::num(ema_loss.get().unwrap_or(0.0))),
            ("valid_acc", ampnet::util::json::num(v.accuracy())),
        ]),
    )?;
    Ok(())
}
