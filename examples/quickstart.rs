//! Quickstart: train the MNIST-like MLP with AMP (async, mak=4) for a few
//! epochs and print the per-epoch metrics. Mirrors Table 1 row 1 at small
//! scale. Requires `make artifacts` (or run with `--backend native`).
//!
//!   cargo run --release --example quickstart

use ampnet::launcher::{args_from, backend_spec, build_model, maybe_write_report};
use ampnet::train::{AmpTrainer, TrainCfg};
use anyhow::Result;

fn main() -> Result<()> {
    ampnet::util::logging::init();
    std::env::set_var("AMP_SCALE", std::env::var("AMP_SCALE").unwrap_or("0.01".into()));
    let args = args_from("--model mlp");
    let (model, target) = build_model("mlp", &args, 16)?;
    let mut cfg = TrainCfg::new(backend_spec(&args)?, 4, 6, target);
    cfg.early_stop = true;
    let (report, _) = AmpTrainer::run(model, &cfg)?;
    println!("epoch, train_loss, valid_acc, inst/s(virtual), staleness");
    for e in &report.epochs {
        println!(
            "{:>5}, {:>10.4}, {:>9.4}, {:>15.1}, {:>9.2}",
            e.epoch,
            e.train.mean_loss(),
            e.valid_accuracy,
            e.train.throughput(),
            e.train.mean_staleness()
        );
    }
    match report.epochs_to_target {
        Some(n) => println!("target reached after {n} epochs ({:.1}s virtual)", report.time_to_target.unwrap()),
        None => println!("target not reached (increase --epochs or AMP_SCALE)"),
    }
    maybe_write_report("quickstart", &report)?;
    Ok(())
}
