//! Quickstart: train the MNIST-like MLP with AMP (async, mak=4) for a few
//! epochs and print the per-epoch metrics. Mirrors Table 1 row 1 at small
//! scale. Requires `make artifacts` (or run with `--backend native`).
//!
//! Validation rides the training stream (DESIGN.md §11); pass
//! `--eval-interleave live` to measure near-current parameters instead of
//! the gated drained-eval semantics.
//!
//!   cargo run --release --example quickstart
//!   cargo run --release --example quickstart -- --eval-interleave live
//!
//! To run the same training across processes (DESIGN.md §12), start
//! worker shards first, then point the head at them:
//!
//!   cargo run --release -- worker --listen /tmp/amp_w0.sock --transport uds
//!   cargo run --release --example quickstart -- --transport uds \
//!       --workers-remote /tmp/amp_w0.sock
//!
//! Chaos run (DESIGN.md §13): script a worker kill mid-stream and watch
//! the head recover — the run exits 0 and prints a `degraded:` line:
//!
//!   cargo run --release --example quickstart -- --transport uds \
//!       --workers-remote /tmp/amp_w0.sock,/tmp/amp_w1.sock \
//!       --fault-plan kill:worker=1@step=200

use ampnet::launcher::{backend_spec, build_model, maybe_write_report, model_args_string};
use ampnet::train::{AmpTrainer, TrainCfg};
use ampnet::transport::RemoteSpec;
use ampnet::util::Args;
use anyhow::Result;

fn main() -> Result<()> {
    ampnet::util::logging::init();
    std::env::set_var("AMP_SCALE", std::env::var("AMP_SCALE").unwrap_or("0.01".into()));
    let args = Args::from_env();
    let model_name = args.str_or("model", "mlp");
    let (model, target) = build_model(&model_name, &args, 16)?;
    let mut cfg = TrainCfg::new(backend_spec(&args)?, 4, 6, target);
    cfg.early_stop = true;
    if let Some(v) = args.get("eval-interleave") {
        cfg.eval_interleave = v.parse()?;
    }
    if let Some(t) = args.get("transport") {
        cfg.transport = Some(t.parse()?);
        cfg.workers_remote = args
            .get("workers-remote")
            .map(|s| {
                s.split(',').map(str::trim).filter(|a| !a.is_empty()).map(String::from).collect()
            })
            .unwrap_or_default();
        cfg.liveness_ms = args.u64_or("liveness-ms", cfg.liveness_ms);
        if let Some(plan) = args.get("fault-plan") {
            cfg.fault_plan = Some(plan.parse()?);
        }
        cfg.recover = !args.flag("no-recover");
        cfg.recover_ckpt = args.get("recover-ckpt").map(String::from);
        cfg.ckpt_every = args.usize_or("ckpt-every", cfg.ckpt_every);
        cfg.remote = Some(RemoteSpec { model: model_name.clone(), args: model_args_string(&args) });
    }
    let (report, _) = AmpTrainer::run(model, &cfg)?;
    println!("epoch, train_loss, valid_acc, inst/s(virtual), staleness, valid_closed_s");
    for e in &report.epochs {
        println!(
            "{:>5}, {:>10.4}, {:>9.4}, {:>15.1}, {:>9.2}, {:>14.3}",
            e.epoch,
            e.train.mean_loss(),
            e.valid_accuracy,
            e.train.throughput(),
            e.train.mean_staleness(),
            e.valid_closed_s
        );
    }
    match report.epochs_to_target {
        Some(n) => println!("target reached after {n} epochs ({:.1}s virtual)", report.time_to_target.unwrap()),
        None => println!("target not reached (increase --epochs or AMP_SCALE)"),
    }
    if let Some(d) = &report.degraded {
        println!(
            "degraded: recovered worker(s) {:?}, re-admitted {} instance(s), {:.2}s recovery",
            d.lost_workers, d.readmitted_instances, d.recovery_seconds
        );
    }
    // distinct report name per interleave mode / transport so CI
    // artifacts keep each variant
    let mut report_name = match cfg.eval_interleave {
        ampnet::train::EvalInterleave::Gated => "quickstart".to_string(),
        mode => format!("quickstart_{mode}"),
    };
    if let Some(kind) = cfg.transport {
        report_name = format!("{report_name}_{kind}");
    }
    maybe_write_report(&report_name, &report)?;
    Ok(())
}
