//! Replica scaling on the list-reduction RNN (paper §5 / Table 1):
//! trains the same model with 1, 2 and 4 replicas of Linear-1 and reports
//! the virtual-time throughput scaling, reproducing the paper's
//! near-linear replica speedup (1x -> 2.5x -> 3.5x rows of Table 1).
//!
//!   cargo run --release --example rnn_replicas

use ampnet::launcher::{args_from, backend_spec, build_model, maybe_write_report};
use ampnet::train::{AmpTrainer, TrainCfg};
use anyhow::Result;

fn main() -> Result<()> {
    ampnet::util::logging::init();
    std::env::set_var("AMP_SCALE", std::env::var("AMP_SCALE").unwrap_or("0.01".into()));
    println!("replicas, mak, inst/s(virtual), speedup, epochs_run");
    let mut base = None;
    for (replicas, mak) in [(1usize, 4usize), (2, 4), (4, 8)] {
        let args = args_from(&format!("--model rnn --replicas {replicas}"));
        let (model, target) = build_model("rnn", &args, 16)?;
        let mut cfg = TrainCfg::new(backend_spec(&args)?, mak, 2, target);
        cfg.early_stop = false;
        let (report, _) = AmpTrainer::run(model, &cfg)?;
        maybe_write_report(&format!("rnn_replicas_{replicas}"), &report)?;
        // skip epoch 1 (compile warmup): use last epoch throughput
        let tput = report.epochs.last().unwrap().train.throughput();
        let b = *base.get_or_insert(tput);
        println!(
            "{replicas:>8}, {mak:>3}, {tput:>15.1}, {:>7.2}x, {}",
            tput / b,
            report.epochs.len()
        );
    }
    Ok(())
}
