//! GGSNN on the QM9-like dataset — the paper's headline sparsity result:
//! the AMP sparse message-passing path vs the dense NHxNH TF-style
//! baseline (9x on CPU in the paper). Reports virtual throughput of both
//! and the ratio.
//!
//!   cargo run --release --example ggsnn_qm9

use ampnet::data::Qm9Gen;
use ampnet::launcher::{args_from, backend_spec, build_model, maybe_write_report, scaled};
use ampnet::train::baseline::{BaselineCfg, SyncBaseline};
use ampnet::train::{AmpTrainer, TargetMetric, TrainCfg};
use anyhow::Result;

fn main() -> Result<()> {
    ampnet::util::logging::init();
    std::env::set_var("AMP_SCALE", std::env::var("AMP_SCALE").unwrap_or("0.001".into()));
    let args = args_from("--model qm9");

    let (model, target) = build_model("qm9", &args, 16)?;
    let mut cfg = TrainCfg::new(backend_spec(&args)?, 16, 2, target);
    cfg.early_stop = false;
    let (amp, _) = AmpTrainer::run(model, &cfg)?;
    let amp_tput = amp.epochs.last().unwrap().train.throughput();

    let bcfg = BaselineCfg {
        backend: backend_spec(&args)?,
        max_epochs: 1,
        target: TargetMetric::MaeRatio { ratio: 4.6, unit: 0.1 },
        lr: 0.003,
        seed: 42,
        max_train_instances: Some(20),
        max_valid_instances: Some(8),
    };
    let dense =
        SyncBaseline::ggsnn_dense_qm9(&bcfg, Qm9Gen::new(42, scaled(117_000).max(20), 8))?;
    let dense_tput = dense.epochs.last().unwrap().train.throughput();

    maybe_write_report("ggsnn_qm9_amp", &amp)?;
    maybe_write_report("ggsnn_qm9_dense", &dense)?;
    println!("amp-sparse:  {amp_tput:.1} graphs/s (virtual, 16 workers)");
    println!("dense (TF):  {dense_tput:.1} graphs/s (16-thread equivalent)");
    println!("speedup:     {:.1}x (paper: ~9x on CPU)", amp_tput / dense_tput);
    println!(
        "amp valid MAE ratio: {:.2} (target 4.6)",
        amp.epochs.last().unwrap().valid_mae / 0.1
    );
    Ok(())
}
