//! Tree-LSTM sentiment (paper §6): streaming per-node training without
//! batching, vs the TF-Fold-style depth-batched synchronous baseline.
//! Prints both convergence traces — the AMP run updates every ~50
//! gradients (2 trees) while the baseline updates once per minibatch,
//! reproducing Fig. 6(c)'s "fewer epochs, lower throughput" shape.
//!
//!   cargo run --release --example tree_sentiment

use ampnet::data::SentiTreeGen;
use ampnet::launcher::{args_from, backend_spec, build_model, maybe_write_report, scaled};
use ampnet::train::baseline::{BaselineCfg, SyncBaseline};
use ampnet::train::{AmpTrainer, TargetMetric, TrainCfg};
use anyhow::Result;

fn main() -> Result<()> {
    ampnet::util::logging::init();
    std::env::set_var("AMP_SCALE", std::env::var("AMP_SCALE").unwrap_or("0.02".into()));
    let args = args_from("--model tree");
    let epochs = 3;

    let (model, target) = build_model("tree", &args, 16)?;
    let mut cfg = TrainCfg::new(backend_spec(&args)?, 16, epochs, target);
    cfg.early_stop = false;
    let (amp, _) = AmpTrainer::run(model, &cfg)?;

    let bcfg = BaselineCfg {
        backend: backend_spec(&args)?,
        max_epochs: epochs,
        target: TargetMetric::Accuracy(0.82),
        lr: 0.003,
        seed: 42,
        max_train_instances: None,
        max_valid_instances: None,
    };
    let fold = SyncBaseline::tree(&bcfg, SentiTreeGen::new(42, scaled(8544), scaled(1101).max(64)), 20)?;
    maybe_write_report("tree_sentiment_amp", &amp)?;
    maybe_write_report("tree_sentiment_fold", &fold)?;

    println!("epoch, amp_valid_acc, amp_trees/s, fold_valid_acc, fold_batches/s");
    for i in 0..epochs {
        let a = amp.epochs.get(i);
        let f = fold.epochs.get(i);
        println!(
            "{:>5}, {:>13.4}, {:>11.1}, {:>14.4}, {:>14.1}",
            i + 1,
            a.map_or(f64::NAN, |e| e.valid_accuracy),
            a.map_or(f64::NAN, |e| e.train.throughput()),
            f.map_or(f64::NAN, |e| e.valid_accuracy),
            f.map_or(f64::NAN, |e| e.train.throughput()),
        );
    }
    Ok(())
}
